"""Async serving sweep: offered load x batch window x straggler rate.

Drives the event-driven ``AsyncServingEngine`` on its virtual clock with
Poisson arrivals and measures, per configuration:

  * wall-clock processing throughput (requests / wall second — the batching
    win: one ``query_batch`` + one model batch per flush window), and
  * virtual-clock latency vs the per-request deadline (p99, miss fraction)
    with TTC-driven straggler re-dispatch repairing the injected tail.

The ``sync/submit_loop`` baseline runs the same trace one blocking
``ServingFleet.submit`` at a time (batches of 1 through the same pipeline).
Acceptance (ISSUE 2): async throughput >= the sync submit loop at batch
window >= 8 on the same trace.  Since PR 3 the sync/async reps are
*interleaved* (contention bursts hit both sides of the ratio); interleaved
recordings on the shared box measure ~1.0-1.3x — the original 2x recording
had the one-shot sync baseline land in a slow burst.  Per-request wall time
*improved* across the board in the same re-measurement.

The ``growth`` rows (ISSUE 3) run a miss-heavy trace over prefilled
40k-entry replica stores: every flush executes + commits, so the paged
store's O(dirty pages) commit-path sync is compared against the emulated
pre-paging full re-upload (``full_resync``) at identical virtual behaviour.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.core.lsh import LSHParams, normalize
from repro.serving import AsyncServingEngine, ReplicaEngine, ServeRequest, ServingFleet
from repro.training.elastic import BackupPolicy

DIM = 32
N_REQUESTS = 600
N_REPLICAS = 3
DEADLINE_S = 0.25
BASE_EXEC_S = 0.08          # per-request execution cost (paper: 70-100 ms)
STRAGGLER_FACTOR = 8.0      # a straggling dispatch takes 8x the base time
LOADS_HZ = (200.0, 1000.0)
BATCH_SIZES = (1, 8, 32)
STRAGGLER_RATES = (0.0, 0.1)


def _max_wait_s(max_batch: int, load_hz: float) -> float:
    """Flush window sized to actually gather ~max_batch arrivals at the
    offered load, capped at a quarter of the deadline budget."""
    if max_batch == 1:
        return 0.001
    return min(DEADLINE_S / 4, max_batch / load_hz)


def _trace(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    base = normalize(rng.standard_normal((24, DIM)).astype(np.float32))
    embs = normalize(base[rng.integers(0, 24, n)]
                     + 0.04 * rng.standard_normal((n, DIM)).astype(np.float32)
                     / np.sqrt(DIM))
    return [ServeRequest(i, "svc", embs[i], threshold=0.9,
                         deadline_s=DEADLINE_S) for i in range(n)]


def _execute(reqs):
    return [round(float(np.sum(np.asarray(r.embedding))), 5) for r in reqs]


def _exec_time_fn(straggler_rate: float, seed: int):
    rng = np.random.default_rng(seed)

    def fn(rid, service, reqs):
        per_req = BASE_EXEC_S * (1 + 0.2 * rng.random())
        if straggler_rate > 0 and rng.random() < straggler_rate:
            per_req *= STRAGGLER_FACTOR
        # sub-linear batch scaling: the model batch amortizes
        return per_req * max(1.0, len(reqs)) ** 0.5

    return fn


def _replicas(params):
    """Warm fleet: replicas carry TTC statistics (production steady state),
    so straggler backup timers are armed from the first dispatch."""
    reps = [ReplicaEngine(i, params, _execute) for i in range(N_REPLICAS)]
    for r in reps:
        r.ttc.observe("svc", BASE_EXEC_S)
    return reps


N_REPS = 5  # best-of wall times: the box is noisy (~2x jitter), virtual
            # metrics are deterministic per seed, so only the wall measure
            # needs reps; sync/async reps are interleaved so contention
            # bursts hit both sides of the speedup ratio

GROWTH_PREFILL = 40_000   # per-replica store size at scenario start
GROWTH_REQS = 256         # unique arrivals: every flush executes + commits


def _growth_rows() -> list:
    """Store-growth scenario (ISSUE 3): production-size stores under a
    miss-heavy trace, so every batch window executes and commits inserts.

    With paged device residency the commit-path sync uploads only the dirty
    pages; the ``full`` rows flip ``full_resync`` to emulate the pre-paging
    whole-matrix re-upload on every commit.  Virtual p99 is sync-invariant
    (uploads are wall cost), so the win shows up as wall-clock time per
    request — the stall the async engine would otherwise surface as p99
    under real load.
    """
    rows: list[Row] = []
    params = LSHParams(dim=DIM, num_tables=5, num_probes=8, seed=7)
    rng = np.random.default_rng(9)
    prefill = normalize(rng.standard_normal(
        (GROWTH_PREFILL, DIM)).astype(np.float32))
    # unique, spread-out arrivals: near-zero reuse at threshold 0.99
    uniq = normalize(rng.standard_normal((GROWTH_REQS, DIM)).astype(np.float32))
    reqs = [ServeRequest(i, "svc", uniq[i], threshold=0.99,
                         deadline_s=DEADLINE_S) for i in range(GROWTH_REQS)]
    def _arm(mode: str, n: int):
        """One fresh fleet + prefill + drained trace of ``n`` requests."""
        reps = _replicas(params)
        for r in reps:
            st = r._store("svc")
            st.full_resync = mode == "full"
            for lo in range(0, GROWTH_PREFILL, 8192):
                st.insert_batch(prefill[lo:lo + 8192],
                                list(range(lo, min(lo + 8192, GROWTH_PREFILL))))
            st.sync_device(ensure=True)  # resident before the trace starts
        eng = AsyncServingEngine(
            params, reps, max_batch=16, max_wait_s=16 / 500.0,
            exec_time_fn=_exec_time_fn(0.0, seed=4))
        arrivals = np.cumsum(
            np.random.default_rng(6).exponential(1.0 / 500.0, n))
        futs = [eng.submit_at(t, r) for t, r in zip(arrivals, reqs[:n])]
        t0 = time.perf_counter()
        eng.drain()
        return (time.perf_counter() - t0,
                [r._store("svc") for r in reps], futs)

    # untimed warmup pass absorbs the one-time jit compiles (prefill hash
    # shapes, gather_top1, page updater) shared by both arms; the timed
    # arms then run interleaved best-of-N so a contention burst hits both
    # sides of the speedup ratio (same idiom as the sweep above)
    _arm("paged", GROWTH_REQS // 4)
    best = {"full": float("inf"), "paged": float("inf")}
    last: dict = {}
    for _ in range(N_REPS):
        for mode in ("full", "paged"):
            wall, stores, futs = _arm(mode, GROWTH_REQS)
            best[mode] = min(best[mode], wall)
            last[mode] = (stores, futs)  # counters/latencies: same every rep
    for mode in ("full", "paged"):
        stores, futs = last[mode]
        pages = sum(s.sync_pages_total for s in stores)
        mb = sum(s.sync_bytes_total for s in stores) / 2**20
        p99 = float(np.percentile(
            [f.result.latency_s for f in futs], 99))
        rows.append((
            f"async_serving/growth/{mode}", best[mode] / GROWTH_REQS * 1e6,
            f"store{GROWTH_PREFILL}/replica miss-heavy trace, wall best-of-"
            f"{N_REPS} interleaved;sync_pages={pages};sync_mb={mb:.0f};"
            f"wall_speedup_vs_full={best['full'] / best[mode]:.2f}x;"
            f"p99_virtual_ms={p99 * 1e3:.1f}"))
    return rows


def run() -> list:
    rows: list[Row] = []
    params = LSHParams(dim=DIM, num_tables=5, num_probes=8, seed=7)
    reqs = _trace(N_REQUESTS)
    configs = [(load, mb, srate) for load in LOADS_HZ
               for mb in BATCH_SIZES for srate in STRAGGLER_RATES]

    # Sync baseline and async sweep run with *interleaved* reps (same idiom
    # as reuse_store_scale): bursty CPU contention on this shared box hits
    # both sides of every speedup ratio instead of whichever side happened
    # to run during the burst, and best-of-reps drops the jit-compile rep.
    sync_wall = float("inf")
    best = {cfg: float("inf") for cfg in configs}
    last: dict = {}
    for _ in range(N_REPS):
        fleet = ServingFleet(params, _replicas(params))
        fleet.engine.exec_time_fn = _exec_time_fn(0.0, seed=1)
        t0 = time.perf_counter()
        for r in reqs:
            fleet.submit(r)
        sync_wall = min(sync_wall, time.perf_counter() - t0)
        for cfg in configs:
            load, max_batch, srate = cfg
            eng = AsyncServingEngine(
                params, _replicas(params),
                backup=BackupPolicy(factor=1.5, max_backups=1),
                max_batch=max_batch,
                max_wait_s=_max_wait_s(max_batch, load),
                exec_time_fn=_exec_time_fn(srate, seed=2))
            rng = np.random.default_rng(3)
            arrivals = np.cumsum(rng.exponential(1.0 / load, N_REQUESTS))
            futs = [eng.submit_at(t, r) for t, r in zip(arrivals, reqs)]
            t0 = time.perf_counter()
            makespan = eng.drain()
            best[cfg] = min(best[cfg], time.perf_counter() - t0)
            last[cfg] = (eng, futs, makespan)  # virtual metrics: same every rep

    sync_tput = N_REQUESTS / sync_wall
    rows.append(("async_serving/sync/submit_loop", sync_wall / N_REQUESTS * 1e6,
                 f"best-of-{N_REPS}, throughput={sync_tput:.0f}req/s_wall"))
    for cfg in configs:
        load, max_batch, srate = cfg
        eng, futs, makespan = last[cfg]
        wall = best[cfg]
        lats = np.asarray([f.result.latency_s for f in futs])
        miss = float(np.mean(lats > DEADLINE_S))
        p99 = float(np.percentile(lats, 99))
        s = eng.stats()
        tput = N_REQUESTS / wall
        rows.append((
            f"async_serving/load{load:.0f}/batch{max_batch}/strag{srate}",
            wall / N_REQUESTS * 1e6,
            f"best-of-{N_REPS}, throughput={tput:.0f}req/s_wall;"
            f"speedup_vs_sync={tput / sync_tput:.2f}x;"
            f"makespan_s={makespan:.2f};"
            f"p99_ms={p99 * 1e3:.1f};deadline_miss_pct={miss * 100:.1f};"
            f"backups={s['backups']};backup_wins={s['backup_wins']};"
            f"executed={s['executed']};en={s['en']};cs={s['cs']};"
            f"aggregated={s['aggregated']}"))
    rows.extend(_growth_rows())
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
