"""Reuse-store scaling: per-task scalar loop vs batched array-native path.

The measurement behind the batched pipeline (DESIGN.md §Array-native store):
sweep batch size x store size and compare

  * ``scalar`` — the seed hot path: one ``probe_one`` device dispatch plus a
    numpy candidate scoring per task (``ReuseStore.query`` in a loop), and
  * ``batch``  — one ``probe_batch`` dispatch + one fused gather/score kernel
    call for the whole batch (``ReuseStore.query_batch``).

Derived column reports the speedup of batch over scalar at the same store
size.  Acceptance target (ISSUE 1): >= 10x at batch >= 256 on a >= 50k store.

The churn sweep (`churn_paged` / `churn_full` rows) measures the paged
device residency (ISSUE 3): insert -> sync -> query cycles at 10k/50k/200k
entries, reporting device-sync pages and bytes per cycle.  Acceptance: at
200k entries the post-insert sync uploads <= 2 pages (O(dirty pages), not
O(store)); the `full` rows emulate the pre-paging full re-upload for A/B.
"""
from __future__ import annotations

import numpy as np

import time

from benchmarks.common import Row
from repro.core import LSHParams, ReuseStore, normalize

STORE_SIZES = (10_000, 50_000)
BATCH_SIZES = (64, 256, 1024, 2048)
SCALAR_SAMPLE = 48  # tasks measured for the per-task scalar baseline
DIM = 64


def _time_us(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def _make_store(n_store: int, seed: int = 0,
                capacity: int | None = None) -> tuple[ReuseStore, np.ndarray]:
    # num_buckets sized to the store (FALCONN convention: ~N buckets) so the
    # multi-probe candidate set stays a small fraction of the store.
    p = LSHParams(dim=DIM, num_tables=5, num_probes=8, num_buckets=16384,
                  family="hyperplane", seed=11)
    store = ReuseStore(p, capacity=n_store + 1 if capacity is None else capacity)
    rng = np.random.default_rng(seed)
    X = normalize(rng.standard_normal((n_store, DIM)).astype(np.float32))
    for lo in range(0, n_store, 8192):  # chunked bulk insert
        store.insert_batch(X[lo:lo + 8192], list(range(lo, min(lo + 8192, n_store))))
    return store, X


def _insert_rows(n_reps: int = 5) -> list:
    """Insert-side sweep: per-item ``insert`` loop vs the grouped-scatter
    ``insert_batch`` (one hash dispatch + one (table, bucket) scatter)."""
    rows: list[Row] = []
    p = LSHParams(dim=DIM, num_tables=5, num_probes=8, num_buckets=16384,
                  family="hyperplane", seed=11)
    rng = np.random.default_rng(2)
    for n_items in (1024, 8192):
        X = normalize(rng.standard_normal((n_items, DIM)).astype(np.float32))
        res = list(range(n_items))
        best_scalar = best_batch = float("inf")
        for _ in range(n_reps):
            s1 = ReuseStore(p, capacity=n_items + 1)
            best_scalar = min(best_scalar, _time_us(
                lambda: [s1.insert(v, r) for v, r in zip(X, res)]))
            s2 = ReuseStore(p, capacity=n_items + 1)
            best_batch = min(best_batch, _time_us(
                lambda: s2.insert_batch(X, res)))
        us_s, us_b = best_scalar / n_items, best_batch / n_items
        rows.append((f"reuse_scale/insert_scalar/n{n_items}", us_s,
                     f"per-item best-of-{n_reps}, hash_one+_table_add loop"))
        rows.append((f"reuse_scale/insert_batch/n{n_items}", us_b,
                     f"per-item best-of-{n_reps}, speedup {us_s / us_b:.1f}x"))
    return rows


CHURN_STORE_SIZES = (10_000, 50_000, 200_000)
CHURN_INSERT = 512   # inserts per churn cycle (spans <= 2 of the 4096 pages)
CHURN_QUERY = 512    # queries per churn cycle (forces the device sync)


def _churn_rows(n_cycles: int = 4) -> list:
    """Insert -> sync -> query churn at scale: device-sync cost per cycle.

    The paged-residency measurement (ISSUE 3): after a batch insert, the
    device sync uploads only the dirty pages — O(dirty), not O(store) — so
    sync pages/bytes stay flat as the store grows 10k -> 200k.  The ``full``
    rows flip the store's ``full_resync`` knob to emulate the pre-paging
    behaviour (every sync re-uploads the whole matrix) on the *same* store
    for a like-for-like A/B.
    """
    rows: list[Row] = []
    rng = np.random.default_rng(5)
    for n_store in CHURN_STORE_SIZES:
        store, X = _make_store(n_store, capacity=2 * n_store)
        warm_q = normalize(
            X[:CHURN_QUERY] + 0.05 * rng.standard_normal(
                (CHURN_QUERY, DIM)).astype(np.float32) / np.sqrt(DIM))
        store.query_batch(warm_q, 0.8)  # jit warmup + device residency
        fresh = normalize(rng.standard_normal(
            (2 * (n_cycles + 1) * CHURN_INSERT, DIM)).astype(np.float32))
        used = 0
        # modes interleave *within* each cycle (paged then full on the same
        # store), so both arms see the same store size to within one insert
        # batch and a contention burst cannot hit only one arm; cycle 0 is
        # an untimed warmup absorbing the jit compiles
        acc = {m: {"ins": 0.0, "q": 0.0, "sync": float("inf"),
                   "pages": 0, "kb": 0.0} for m in ("paged", "full")}
        for cycle in range(n_cycles + 1):
            for mode in ("paged", "full"):
                store.full_resync = mode == "full"
                batch = fresh[used:used + CHURN_INSERT]
                res = list(range(used, used + CHURN_INSERT))
                used += CHURN_INSERT
                i_us = _time_us(lambda: store.insert_batch(batch, res))
                b0 = store.sync_bytes_total
                s_us = _time_us(lambda: store.sync_device(ensure=True))
                p, by = store.last_sync_pages, store.sync_bytes_total - b0
                qq_us = _time_us(lambda: store.query_batch(warm_q, 0.8))
                if cycle == 0:
                    continue
                a = acc[mode]
                a["ins"] += i_us
                a["q"] += qq_us
                a["sync"] = min(a["sync"], s_us)
                a["pages"] += p
                a["kb"] += by / 1024
        store.full_resync = False
        for mode in ("paged", "full"):
            a = acc[mode]
            # the row metric is the post-insert device sync itself (best-of-
            # cycles): insert and query wall are sync-invariant between the
            # modes and would otherwise bury the 1-vs-50-page signal in
            # shared-box query noise
            rows.append((
                f"reuse_scale/churn_{mode}/store{n_store}", a["sync"],
                f"sync_us best-of-{n_cycles} (cycle=insert{CHURN_INSERT}+sync+"
                f"query{CHURN_QUERY}, modes interleaved);"
                f"sync_pages/cycle={a['pages'] / n_cycles:.1f};"
                f"sync_kb/cycle={a['kb'] / n_cycles:.0f};"
                f"insert_us={a['ins'] / n_cycles:.0f};"
                f"query_us={a['q'] / n_cycles:.0f}"))
    return rows


SKEW_STORE = 20_000
SKEW_QUERY = 1_000
SKEW_CENTERS = 256
SKEW_NOISE = 0.02
SKEW_THRESHOLD = 0.9


def _skewed_occupancy_rows() -> list:
    """Recall-study slice (ISSUE 5): Zipf-skewed bucket occupancy vs recall.

    Extends the ``multiprobe`` recall methodology (held-out stream items,
    label-match hit criterion) to the *skewed* stores the federation layer's
    reuse-affinity policy peeks into: cluster popularity ~ Zipf(s) makes a
    few LSH buckets far denser than the rest, ring overflow drops pointers
    there first, and this row set pins what recall the
    ``query_batch(peek=True)`` affinity hint actually delivers — overall and
    split hot (top-decile clusters) vs cold — alongside the occupancy skew
    that produced it (top-decile bucket share, max fill vs bucket_cap,
    overflow count).  ``zipf0.0`` is the uniform control.
    """
    rows: list[Row] = []
    p = LSHParams(dim=DIM, num_tables=5, num_probes=8, num_buckets=4096,
                  family="hyperplane", seed=11)
    n_hot = max(SKEW_CENTERS // 10, 1)
    for s in (0.0, 1.1, 1.6):
        rng = np.random.default_rng(17)
        base = normalize(rng.standard_normal(
            (SKEW_CENTERS, DIM)).astype(np.float32))
        pop = 1.0 / np.arange(1, SKEW_CENTERS + 1) ** s
        pop /= pop.sum()
        n = SKEW_STORE + SKEW_QUERY
        labels = rng.choice(SKEW_CENTERS, n, p=pop)
        X = normalize(base[labels] + SKEW_NOISE * rng.standard_normal(
            (n, DIM)).astype(np.float32))
        # auto cap = the federation bench's operating point; cap 4 stresses
        # ring overflow so the skew-induced recall cliff is visible
        for cap in (None, 4):
            store = ReuseStore(p, capacity=n + 8, bucket_cap=cap)
            store.insert_batch(X[:SKEW_STORE], list(labels[:SKEW_STORE]))
            fill = np.sort(store._fill.reshape(-1))[::-1]
            total = max(int(fill.sum()), 1)
            top10 = float(fill[: max(fill.size // 10, 1)].sum()) / total
            hits = {True: [0, 0], False: [0, 0]}  # hot? -> [hits, queries]
            out = store.query_batch(X[SKEW_STORE:], SKEW_THRESHOLD,
                                    peek=True)
            for lab, (res, _, idx) in zip(labels[SKEW_STORE:], out):
                bucket = hits[bool(lab < n_hot)]
                bucket[1] += 1
                bucket[0] += int(idx is not None and res == lab)
            recall = sum(b[0] for b in hits.values()) / SKEW_QUERY
            rh = hits[True][0] / max(hits[True][1], 1)
            rc = hits[False][0] / max(hits[False][1], 1)
            rows.append((
                f"reuse_scale/skewed_occupancy/zipf{s}/"
                f"cap{store.bucket_cap}", 0.0,
                f"recall_pct={100 * recall:.1f};"
                f"recall_hot_pct={100 * rh:.1f};"
                f"recall_cold_pct={100 * rc:.1f};"
                f"top10_bucket_share={top10:.2f};"
                f"max_fill={int(fill[0])};bucket_cap={store.bucket_cap};"
                f"overflows={store.overflows};"
                f"hot_queries={hits[True][1]};threshold={SKEW_THRESHOLD}"))
    return rows


def run(n_reps: int = 7) -> list:
    rows: list[Row] = []
    rng = np.random.default_rng(1)
    for n_store in STORE_SIZES:
        store, X = _make_store(n_store)
        queries = normalize(
            X[:max(BATCH_SIZES)]
            + 0.05 * rng.standard_normal((max(BATCH_SIZES), DIM)).astype(np.float32)
            / np.sqrt(DIM))
        q_scal = queries[:SCALAR_SAMPLE]
        scalar_fn = lambda: [store.query(q, 0.8) for q in q_scal]  # noqa: E731
        batch_fns = {b: (lambda qb=queries[:b]: store.query_batch(qb, 0.8))
                     for b in BATCH_SIZES}
        # Warmup (jit compiles), then interleave scalar/batch reps so bursty
        # CPU contention hits both sides of the ratio; OS noise is strictly
        # additive, so best-of-reps is the stable capability measure.
        scalar_fn()
        for fn in batch_fns.values():
            fn()
        best_scalar = float("inf")
        best_batch = {b: float("inf") for b in BATCH_SIZES}
        for _ in range(n_reps):
            best_scalar = min(best_scalar, _time_us(scalar_fn))
            for b, fn in batch_fns.items():
                best_batch[b] = min(best_batch[b], _time_us(fn))
        us_scalar = best_scalar / len(q_scal)
        rows.append((f"reuse_scale/scalar/store{n_store}", us_scalar,
                     f"per-task best-of-{n_reps}, probe_one+numpy loop"))
        for b in BATCH_SIZES:
            us = best_batch[b] / b
            rows.append((f"reuse_scale/batch{b}/store{n_store}", us,
                         f"per-task best-of-{n_reps}, speedup {us_scalar / us:.1f}x"))
    rows.extend(_insert_rows())
    rows.extend(_churn_rows())
    rows.extend(_skewed_occupancy_rows())
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
