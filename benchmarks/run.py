"""Benchmark driver: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see DESIGN.md §8 for the
benchmark <-> paper artifact mapping).  Select subsets with
``python -m benchmarks.run [names...]``; pass ``--json <path>`` to also emit
a machine-readable ``BENCH_*.json`` so the perf trajectory can be tracked
across PRs.
"""
from __future__ import annotations

import json
import sys
import time

BENCHES = (
    "hashing_time",       # Table III
    "search_accuracy",    # Table IV (a) + (b)
    "rfib_lookup",        # Fig. 6 + rFIB size
    "completion_time",    # Figs. 8a/8b + 9a/9b
    "reuse_accuracy",     # Figs. 8c + 9c
    "percent_reuse",      # Figs. 8d + 9d
    "cache_sweep",        # §V-C cache-size study
    "forwarding_error",   # Fig. 10
    "icedge_compare",     # Fig. 11
    "serving_reuse",      # beyond-paper: reuse-aware LM serving
    "multiprobe",         # beyond-paper: probe depth vs recall vs cost
    "reuse_store_scale",  # beyond-paper: batched vs scalar reuse pipeline
    "fused_query",        # beyond-paper: one-dispatch fused vs staged query
    "async_serving",      # beyond-paper: event-driven serving core sweep
    "cosim",              # beyond-paper: edge-to-TPU co-simulation sweep
    "federation",         # beyond-paper: cross-EN offload policy sweep
    "fault_recovery",     # beyond-paper: fault injection + recovery under loss
    "migration",          # beyond-paper: store migration under fleet churn
    "sanitizer_overhead",  # armed vs disarmed invariant-sanitizer cost
    "obs_overhead",       # armed vs disarmed tracing/profiling cost
    "roofline",           # §Roofline (reads dry-run artifacts)
)


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        try:
            json_path = args[i + 1]
        except IndexError:
            raise SystemExit("--json requires a path argument")
        del args[i:i + 2]
    selected = args or BENCHES
    print("name,us_per_call,derived")
    failures = []
    records = []
    for bench in selected:
        mod = __import__(f"benchmarks.{bench}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 — report, keep the suite going
            failures.append((bench, repr(e)))
            print(f"{bench}/ERROR,0,{e!r}")
            records.append({"bench": bench, "name": f"{bench}/ERROR",
                            "us_per_call": 0.0, "derived": repr(e)})
            continue
        for name, us, derived in rows:
            print(f'{name},{us:.2f},"{derived}"')
            records.append({"bench": bench, "name": name,
                            "us_per_call": round(float(us), 2),
                            "derived": str(derived)})
        print(f"# {bench} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump({"benches": list(selected), "rows": records}, f, indent=1)
        print(f"# wrote {json_path}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
