"""Benchmark driver: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see DESIGN.md §8 for the
benchmark <-> paper artifact mapping).  Select subsets with
``python -m benchmarks.run [names...]``.
"""
from __future__ import annotations

import sys
import time

BENCHES = (
    "hashing_time",       # Table III
    "search_accuracy",    # Table IV (a) + (b)
    "rfib_lookup",        # Fig. 6 + rFIB size
    "completion_time",    # Figs. 8a/8b + 9a/9b
    "reuse_accuracy",     # Figs. 8c + 9c
    "percent_reuse",      # Figs. 8d + 9d
    "cache_sweep",        # §V-C cache-size study
    "forwarding_error",   # Fig. 10
    "icedge_compare",     # Fig. 11
    "serving_reuse",      # beyond-paper: reuse-aware LM serving
    "multiprobe",         # beyond-paper: probe depth vs recall vs cost
    "roofline",           # §Roofline (reads dry-run artifacts)
)


def main() -> None:
    selected = sys.argv[1:] or BENCHES
    print("name,us_per_call,derived")
    failures = []
    for bench in selected:
        mod = __import__(f"benchmarks.{bench}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 — report, keep the suite going
            failures.append((bench, repr(e)))
            print(f"{bench}/ERROR,0,{e!r}")
            continue
        for name, us, derived in rows:
            print(f'{name},{us:.2f},"{derived}"')
        print(f"# {bench} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
