"""Beyond-paper analysis: multi-probe depth vs recall vs candidate cost.

The paper motivates multi-probe LSH as the practical alternative to 100+
tables (§II).  This quantifies the trade our implementation provides: probes
per table vs NN-recall vs candidates examined (≈ search cost)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lsh import LSHParams
from repro.core.reuse_store import ReuseStore
from repro.data import DATASETS, make_stream


def run(n_store: int = 3000, n_query: int = 300) -> list:
    rows = []
    spec = DATASETS["pandaset"]
    X, labels = make_stream(spec, n_store + n_query, seed=13)
    for probes in (1, 2, 4, 8, 16):
        store = ReuseStore(
            LSHParams(dim=spec.dim, num_tables=1, num_probes=probes, seed=9),
            capacity=n_store + 8)
        store.insert_batch(X[:n_store], list(labels[:n_store]))
        hit = 0
        for x, l in zip(X[n_store:], labels[n_store:]):
            res, _, idx = store.query(x, threshold=-1.0)
            hit += int(idx is not None and res == l)
        cand = float(np.mean(store.candidate_counts)) if store.candidate_counts else 0
        rows.append((f"multiprobe/probes={probes}", 0.0,
                     f"recall_pct={100 * hit / n_query:.1f};"
                     f"mean_candidates={cand:.1f};tables=1"))
    return rows
