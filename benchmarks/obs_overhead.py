"""Observability-overhead benchmark (ISSUE 10): traced vs plain sim cost.

The observability layer (``repro/obs``, DESIGN.md §Observability) arms
per-task tracing + the hot-loop profiler on the event-loop dispatch path
the same way the sanitizer does.  Two contracts are asserted here:

* **disarmed is free AND bit-identical** — the plain run (tracer and
  profiler both ``None``) must produce the same simulation results as a
  fully armed run (completion count, reuse fraction, virtual end time):
  the tracer observes the virtual timeline, never perturbs it;
* **armed stays cheap** — ``RESERVOIR_TRACE=1 RESERVOIR_PROFILE=1`` must
  cost < 10% wall overhead in the best interleaved off/on pair (identical
  seeded workload), so tracing a real co-sim is routine, not a special
  build.

A third section exercises the armed path end-to-end on a federated co-sim
with chaos faults: the exported document must be valid Chrome trace-event
JSON (parsed back), carry zero unclosed spans, and the profiler report
must rank the EventLoop callback sites.

Standalone: ``python -m benchmarks.obs_overhead [--smoke] [--json P]``
(CI runs ``--smoke``); also registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import networkx as nx
import numpy as np

from benchmarks.common import Row
from repro.core import LSHParams, ReservoirNetwork
from repro.core.edge_node import Service
from repro.core.lsh import normalize
from repro.faults.chaos import ChaosController
from repro.faults.plan import CrashEvent, FaultPlan, LinkFault

DIM = 32
N_ENS = 3
N_USERS = 2
THRESHOLD = 0.9
LOAD_HZ = 50.0
OVERHEAD_BUDGET = 0.10  # armed tracing+profiling must cost < 10%

_ENV_KEYS = ("RESERVOIR_TRACE", "RESERVOIR_PROFILE")


def _stream(n: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = normalize(rng.standard_normal((24, DIM)).astype(np.float32))
    picks = rng.integers(0, 24, n)
    return normalize(base[picks] + 0.02 * rng.standard_normal(
        (n, DIM)).astype(np.float32))


def _build(n_tasks: int, armed: bool, seed: int = 0,
           offload_policy=None, chaos: bool = False) -> ReservoirNetwork:
    params = LSHParams(dim=DIM, num_tables=3, num_probes=6, seed=11)
    g = nx.Graph()
    ens = [f"en{i}" for i in range(N_ENS)]
    for en in ens:
        g.add_edge("core", en, delay=0.002)
    prev = {k: os.environ.get(k) for k in _ENV_KEYS}
    for k in _ENV_KEYS:
        os.environ[k] = "1" if armed else "0"
    try:
        net = ReservoirNetwork(
            g, ens, params, seed=seed, offload_policy=offload_policy,
            retx_timeout_s=0.25 if chaos else None,
            pit_lifetime_s=2.0 if chaos else None)
    finally:
        for k, v in prev.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    assert (net.loop.tracer is not None) == armed
    assert (net.loop.profiler is not None) == armed
    if chaos:
        ChaosController(net, FaultPlan(
            seed=3,
            links=[LinkFault(loss=0.05)],
            crashes=[CrashEvent(node=ens[-1], at=1.5)]))
    net.register_service(Service(
        "/svc", execute=lambda x: round(float(np.sum(x)), 5),
        exec_time_s=(0.010, 0.015), input_dim=DIM))
    for u in range(N_USERS):
        net.add_user(f"u{u}", "core")
    X = _stream(n_tasks)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.cumsum(rng.exponential(1.0 / LOAD_HZ, n_tasks))
    for i, (t, x) in enumerate(zip(arrivals, X)):
        net.submit_task(f"u{i % N_USERS}", "svc", x, THRESHOLD,
                        at_time=float(t))
    return net


def _run_once(n_tasks: int, armed: bool, seed: int = 0):
    """One seeded run -> (wall seconds, result signature).

    Times ``net.run()`` only: submission merely schedules closures (their
    tracer work fires inside the loop and IS measured), while the submit
    loop's numpy staging would just add noise to both arms."""
    net = _build(n_tasks, armed, seed=seed)
    t0 = time.perf_counter()
    net.run()
    wall = time.perf_counter() - t0
    m = net.metrics
    sig = (len(m.completed()), round(m.reuse_fraction(), 9),
           round(net.loop.now, 9))
    return wall, sig


def _armed_cosim(n_tasks: int) -> List[Row]:
    """Armed end-to-end: federated + chaos co-sim -> valid trace export
    plus a profiler report ranking the EventLoop callback sites."""
    net = _build(n_tasks, armed=True, offload_policy="least-loaded",
                 chaos=True)
    net.run()
    tr, prof = net.loop.tracer, net.loop.profiler
    doc = json.loads(json.dumps(tr.to_chrome()))  # round-trip: valid JSON
    assert doc["traceEvents"], "armed run exported no events"
    assert not tr.open_spans(), f"unclosed spans: {tr.open_spans()}"
    task_spans = [e for e in doc["traceEvents"]
                  if e.get("ph") == "X" and e["name"] == "task"]
    assert task_spans, "no task spans in the export"
    rows = prof.rows()
    assert rows and rows[0]["wall_s"] >= rows[-1]["wall_s"], \
        "profiler rows not ranked"
    top = rows[0]
    return [
        ("obs_overhead/armed_cosim", top["wall_s"] * 1e6,
         f"events={len(doc['traceEvents'])};task_spans={len(task_spans)};"
         f"sites={len(rows)};top_site={top['site']};"
         f"top_count={top['count']}"),
    ]


def run(smoke: bool = True) -> list:
    """Interleaved off/on pairs, disarmed-vs-armed, one seeded workload.

    Overhead estimator: per-pair on/off wall ratios (each pair runs
    back-to-back so a noisy-neighbour slow phase hits both arms alike and
    cancels in the ratio).  The budget gate uses the BEST (minimum) pair —
    the pairwise analogue of best-of wall timing: the observation least
    inflated by machine noise.  The median is reported alongside; on a
    quiet machine the two agree.  A best-of across arms (the sanitizer
    benchmark's estimator) is fragile on shared machines where run-to-run
    wall time swings far more than the effect being measured."""
    n_tasks = 200 if smoke else 600
    reps = 5 if smoke else 7
    best = {"off": float("inf"), "on": float("inf")}
    sigs = {}
    ratios = []
    for arm, armed in (("off", False), ("on", True)):  # warm caches/JIT
        _run_once(n_tasks, armed)
    for _ in range(reps):
        pair = {}
        for arm, armed in (("off", False), ("on", True)):
            wall, sig = _run_once(n_tasks, armed)
            pair[arm] = wall
            best[arm] = min(best[arm], wall)
            sigs.setdefault(arm, sig)
            if sigs[arm] != sig:
                raise AssertionError(
                    f"nondeterministic arm {arm}: {sigs[arm]} vs {sig}")
        ratios.append(pair["on"] / pair["off"])
    if sigs["off"] != sigs["on"]:
        raise AssertionError(
            "observability perturbed the simulation: "
            f"off={sigs['off']} on={sigs['on']}")
    ratio = float(np.min(ratios))
    median = float(np.median(ratios))
    overhead_pct = (ratio - 1.0) * 100
    assert ratio < 1.0 + OVERHEAD_BUDGET, (
        f"armed observability costs {overhead_pct:.1f}% in the BEST "
        f"interleaved pair (budget {OVERHEAD_BUDGET * 100:.0f}%; "
        f"median pair {100 * (median - 1.0):+.1f}%)")
    us = {arm: best[arm] / n_tasks * 1e6 for arm in best}
    rows: List[Row] = [
        ("obs_overhead/off", us["off"],
         f"tasks={n_tasks} completed={sigs['off'][0]}"),
        ("obs_overhead/on", us["on"],
         f"best_pair_ratio={ratio:.3f} overhead={overhead_pct:+.1f}% "
         f"median_pair_ratio={median:.3f} "
         f"budget=<{OVERHEAD_BUDGET * 100:.0f}%"),
    ]
    rows += _armed_cosim(n_tasks)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small task count (CI)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
