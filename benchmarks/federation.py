"""Federation sweep (ISSUE 5): Zipf-skewed EN load x offload policy x ENs.

The multi-EN promise of the paper's co-simulation, measured: N ENs on one
hub topology receive a Zipf-skewed arrival stream — the initial rFIB bucket
partition is Zipf-weighted (EN0 owns the lion's share, the way a mis-sized
static partition does in practice), so the hottest EN sees ~60% of the
arrivals while its neighbours idle.  Per (policy, load) configuration we
record p99 / mean completion time, the reuse-hit rate, the scratch-vs-reuse
gap (paper Fig. 8 shape; instant reuse only, window-dedup followers
excluded), the hottest-EN arrival share, and federation counters (offloads,
remote hits, rebalances).

Policies (src/repro/federation/policy.py):
  * local-only     — every miss executes where the rFIB routed it (the
                     pre-federation baseline; the hot EN queues).
  * least-loaded   — gossiped-telemetry load balancing, blind to reuse:
                     misses scatter to idle ENs, stranding their inserted
                     results away from the bucket owners future
                     near-duplicates are routed to.
  * reuse-affinity — Deduplicator-style co-design: a peek hint turns misses
                     into remote *hits* where displaced content lives, and
                     executes elsewhere only with bucket-affinity weighting.

Acceptance (ISSUE 5), evaluated at the hottest load point:
  * reuse-affinity p99 >= 1.5x lower than local-only,
  * reuse-affinity scratch-vs-reuse gap >= 4x,
  * reuse-affinity reuse-hit rate > least-loaded's.

A final row runs reuse-affinity with aggressive load-driven rebalance knobs:
persistent miss skew must shift bucket *ownership* (EN0's share shrinks),
not just individual tasks.

Standalone: ``python -m benchmarks.federation [--smoke] [--json PATH]`` (CI
runs ``--smoke``); also registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

import networkx as nx
import numpy as np

from benchmarks.common import Row
from repro.core import LSHParams, ReservoirNetwork
from repro.core.edge_node import Service
from repro.core.lsh import normalize

N_TASKS = 600
N_USERS = 4
N_ENS = 6
THRESHOLD = 0.9
LOADS_HZ = (80.0, 160.0)
POLICIES = ("local-only", "least-loaded", "reuse-affinity")
EN_SKEW = 1.0        # Zipf exponent of the initial bucket-partition weights
CONTENT_CENTERS = 48
CONTENT_SKEW = 1.1   # Zipf exponent of cluster popularity
CONTENT_NOISE = 0.02
DIM = 64


def _fed_topology(n_ens: int, link_delay_s: float = 0.005):
    """Hub-and-spoke: every EN one core link from the hub (equal RTTs, so
    policy differences are policy differences, not topology accidents)."""
    g = nx.Graph()
    ens = [f"en{i}" for i in range(n_ens)]
    for en in ens:
        g.add_edge("core", en, delay=link_delay_s)
    return g, ens


def _zipf_stream(n: int, seed: int = 7) -> np.ndarray:
    """Cluster stream with Zipf-distributed cluster popularity."""
    rng = np.random.default_rng(seed)
    base = normalize(rng.standard_normal(
        (CONTENT_CENTERS, DIM)).astype(np.float32))
    p = 1.0 / np.arange(1, CONTENT_CENTERS + 1) ** CONTENT_SKEW
    p /= p.sum()
    picks = rng.choice(CONTENT_CENTERS, n, p=p)
    return normalize(base[picks] + CONTENT_NOISE * rng.standard_normal(
        (n, DIM)).astype(np.float32))


def _run_one(policy: str, load_hz: float, n_tasks: int, n_ens: int,
             federation_kw: Optional[dict] = None, seed: int = 0) -> dict:
    params = LSHParams(dim=DIM, num_tables=5, num_probes=8, seed=11)
    g, ens = _fed_topology(n_ens)
    net = ReservoirNetwork(
        g, ens, params, seed=seed, offload_policy=policy,
        federation_kw=federation_kw if federation_kw is not None
        else {"rebalance": False})
    net.register_service(Service(
        "/svc", execute=lambda x: round(float(np.sum(x)), 5),
        exec_time_s=(0.070, 0.100), input_dim=DIM))
    # Zipf-weighted initial partition: EN_i's bucket share ~ 1/(i+1)^skew —
    # the "hottest-EN" arrival skew every policy is then confronted with
    w = 1.0 / np.arange(1, n_ens + 1) ** EN_SKEW
    net.rebalance_service("svc", weights=list(w / w.sum()))
    for u in range(N_USERS):
        net.add_user(f"u{u}", "core")
    X = _zipf_stream(n_tasks, seed=7)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.cumsum(rng.exponential(1.0 / load_hz, n_tasks))
    for i, (t, x) in enumerate(zip(arrivals, X)):
        net.submit_task(f"u{i % N_USERS}", "svc", x, THRESHOLD,
                        at_time=float(t))
    net.run()
    m = net.metrics
    done = m.completed()
    assert len(done) == n_tasks, f"{n_tasks - len(done)} tasks incomplete"
    cts = np.asarray([r.completion_time for r in done])
    instant = [r.completion_time for r in done
               if r.reuse is not None and not r.aggregated]
    scratch = m.mean_completion(kind=(None,))
    per_en = [net.edge_nodes[n].stats["executed"]
              + net.edge_nodes[n].stats["reused"] for n in ens]
    fs = net.federator.stats
    e0 = [e for e in net.forwarders["core"].rfib.entries("svc")
          if e.en_prefix == "/en/en0"]
    share0 = ((e0[0].ranges[0][1] - e0[0].ranges[0][0] + 1)
              / params.effective_buckets if e0 else 0.0)
    return {
        "p99_ms": float(np.percentile(cts, 99)) * 1e3,
        "mean_ms": float(cts.mean()) * 1e3,
        "reuse_pct": m.reuse_fraction() * 100,
        "gap": (scratch / float(np.mean(instant)) if instant
                else float("nan")),
        "hot_share": max(per_en) / max(sum(per_en), 1),
        "en0_bucket_share": share0,
        "offloads": fs["offloads"],
        "remote_hits": fs["remote_hits"],
        "remote_execs": fs["remote_execs"],
        "rebalances": fs["rebalances"],
        # registry-sourced per-phase latency decomposition (one source of
        # truth shared with launch/serve and benchmarks/cosim)
        **net.registry.phase_summary(),
    }


def _derived(r: dict) -> str:
    phases = ";".join(f"{p}_ms={r[p + '_ms']:.2f}"
                      for p in ("forward", "search", "execute", "aggregate"))
    return (f"p99_ms={r['p99_ms']:.1f};mean_ms={r['mean_ms']:.1f};"
            f"reuse_pct={r['reuse_pct']:.1f};gap={r['gap']:.2f}x;"
            f"hot_share={r['hot_share']:.2f};offloads={r['offloads']};"
            f"remote_hits={r['remote_hits']};rebalances={r['rebalances']};"
            f"{phases}")


def run(smoke: bool = False) -> list:
    rows: list[Row] = []
    n_tasks = 150 if smoke else N_TASKS
    n_ens = 4 if smoke else N_ENS
    loads = (120.0,) if smoke else LOADS_HZ
    results: dict = {}
    for load in loads:
        for policy in POLICIES:
            r = _run_one(policy, load, n_tasks, n_ens)
            results[(policy, load)] = r
            rows.append((f"federation/{policy}/load{load:.0f}",
                         r["p99_ms"] * 1e3, _derived(r)))
    # load-driven rebalance: persistent miss skew must shift bucket
    # ownership — EN0's Zipf-inflated share shrinks toward its fair slice
    reb = _run_one("reuse-affinity", loads[-1], n_tasks, n_ens,
                   federation_kw={"rebalance": True,
                                  "rebalance_every_rounds": 10,
                                  "rebalance_min_tasks": 10,
                                  "rebalance_skew": 1.8,
                                  "rebalance_persistence": 2})
    rows.append((f"federation/rebalance/load{loads[-1]:.0f}",
                 reb["p99_ms"] * 1e3,
                 _derived(reb)
                 + f";en0_share={reb['en0_bucket_share']:.2f}"
                 f";en0_share_initial={results[('reuse-affinity', loads[-1])]['en0_bucket_share']:.2f}"))
    # --- acceptance at the hottest load point (ISSUE 5)
    hot = loads[-1]
    local, ll, ra = (results[(p, hot)] for p in POLICIES)
    p99_ratio = local["p99_ms"] / ra["p99_ms"]
    ok = (p99_ratio >= 1.5 and ra["gap"] >= 4.0
          and ra["reuse_pct"] > ll["reuse_pct"])
    rows.append((
        "federation/acceptance", 0.0,
        f"p99_local/p99_affinity={p99_ratio:.2f}x(accept>=1.5);"
        f"affinity_gap={ra['gap']:.2f}x(accept>=4);"
        f"affinity_reuse={ra['reuse_pct']:.1f}%>"
        f"least_loaded_reuse={ll['reuse_pct']:.1f}%;"
        f"{'PASS' if ok else 'FAIL'}"))
    if not ok and not smoke:
        raise AssertionError(
            f"federation acceptance: p99 ratio {p99_ratio:.2f}x, "
            f"gap {ra['gap']:.2f}x, reuse {ra['reuse_pct']:.1f}% "
            f"vs least-loaded {ll['reuse_pct']:.1f}%")
    if smoke:
        # CI guard: every task completes under every policy (asserted in
        # _run_one) and the federation machinery demonstrably engaged
        assert ra["offloads"] > 0, "smoke: reuse-affinity never offloaded"
        assert reb["rebalances"] >= 1, "smoke: rebalance never triggered"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small configuration (CI guard)")
    ap.add_argument("--json", default=None,
                    help="also write rows to this path "
                         "(BENCH_federation.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    if args.json:
        records = [{"bench": "federation", "name": n,
                    "us_per_call": round(float(u), 2), "derived": str(d)}
                   for n, u, d in rows]
        with open(args.json, "w") as f:
            json.dump({"benches": ["federation"], "rows": records}, f,
                      indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
