"""Paper Table III: LSH hashing time per task vs number of tables."""
from __future__ import annotations

import numpy as np

from repro.core.lsh import LSHParams, get_lsh
from .common import Row, timeit


def run(dim: int = 64) -> list:
    rows: list = []
    rng = np.random.default_rng(0)
    x1 = rng.standard_normal((1, dim)).astype(np.float32)
    xb = rng.standard_normal((256, dim)).astype(np.float32)
    for t in (1, 5, 10):
        lsh = get_lsh(LSHParams(dim=dim, num_tables=t, num_probes=8, seed=2))
        us = timeit(lambda: np.asarray(lsh.hash_batch(x1)))
        us_b = timeit(lambda: np.asarray(lsh.hash_batch(xb)))
        rows.append((f"hash_time/tables={t}", us,
                     f"ms_per_task={us / 1e3:.3f};paper_ms={ {1: 0.4, 5: 1.7, 10: 3.3}[t] };"
                     f"batched_us_per_task={us_b / 256:.1f}"))
    return rows
