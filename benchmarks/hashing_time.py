"""Paper Table III: LSH hashing time per task vs number of tables.

Three arms per table count:

* scalar   — ``hash_batch`` on a single task (the paper's measurement),
* batched  — ``hash_batch`` amortised over a 256-task batch,
* fused    — the one-dispatch ``ops.lsh_buckets`` kernel (rotation matmul +
  cross-polytope vertex ids + bucket mixing folded into the kernel
  epilogue; ISSUE 7 satellite), same 256-task batch.  Tile size honours
  ``RESERVOIR_HASH_BLOCK_B``.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.lsh import LSHParams, get_lsh
from repro.kernels import ops
from .common import Row, timeit


def run(dim: int = 64) -> list:
    rows: list = []
    rng = np.random.default_rng(0)
    x1 = rng.standard_normal((1, dim)).astype(np.float32)
    xb = rng.standard_normal((256, dim)).astype(np.float32)
    block_b = os.environ.get("RESERVOIR_HASH_BLOCK_B", "128")
    for t in (1, 5, 10):
        lsh = get_lsh(LSHParams(dim=dim, num_tables=t, num_probes=8, seed=2))
        nb = lsh.params.num_buckets
        us = timeit(lambda: np.asarray(lsh.hash_batch(x1)))
        us_b = timeit(lambda: np.asarray(lsh.hash_batch(xb)))
        us_k = timeit(lambda: np.asarray(ops.lsh_buckets(xb, lsh.rotations, nb)))
        rows.append((f"hash_time/tables={t}", us,
                     f"ms_per_task={us / 1e3:.3f};paper_ms={ {1: 0.4, 5: 1.7, 10: 3.3}[t] };"
                     f"batched_us_per_task={us_b / 256:.1f};"
                     f"fused_kernel_us_per_task={us_k / 256:.1f};"
                     f"hash_block_b={block_b}"))
    return rows
