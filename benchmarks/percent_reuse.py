"""Paper Figs. 8d/9d: percent of tasks satisfied by reuse vs threshold."""
from __future__ import annotations

import numpy as np

from .common import DATASET_ORDER, run_network

THRESHOLDS = (0.5, 0.7, 0.8, 0.9, 0.95)


def run(n_tasks: int = 250) -> list:
    rows = []
    means = []
    for dataset in DATASET_ORDER:
        pr = []
        for thr in THRESHOLDS:
            _, s = run_network(dataset, n_tasks=n_tasks, threshold=thr)
            pr.append(s["reuse_pct"])
        means.append(np.mean(pr))
        der = ";".join(f"thr{t}={p:.1f}" for t, p in zip(THRESHOLDS, pr))
        _, s9 = run_network(dataset, n_tasks=n_tasks, threshold=0.9)
        der += (f";cs_pct@0.9={s9['reuse_pct_cs']:.1f}"
                f";en_pct@0.9={s9['reuse_pct_en']:.1f}")
        rows.append((f"percent_reuse/{dataset}", 0.0, der))
    rows.append(("percent_reuse/average", 0.0,
                 f"mean_over_datasets={np.mean(means):.1f}pct;paper_avg~50-52pct;"
                 f"paper_cctv_max=88-91pct"))
    return rows
