"""Paper Table IV: LSH nearest-neighbour search accuracy (a) and times (b)."""
from __future__ import annotations

import numpy as np

from repro.core.lsh import LSHParams
from repro.core.reuse_store import ReuseStore
from repro.data import DATASETS, make_stream
from .common import DATASET_ORDER, timeit


def run(n_store: int = 4000, n_query: int = 400) -> list:
    rows = []
    # (a) accuracy: retrieved NN has the query's label (same object/scene)
    from repro.data.synthetic import _labeler

    for dataset in DATASET_ORDER:
        spec = DATASETS[dataset]
        label = _labeler(spec)
        X, labels = make_stream(spec, n_store + n_query, seed=5)
        for t in (1, 5, 10):
            store = ReuseStore(LSHParams(dim=spec.dim, num_tables=t,
                                         num_probes=8, seed=7),
                               capacity=n_store + 8)
            store.insert_batch(X[:n_store], list(labels[:n_store]))
            hit = 0
            for x, l in zip(X[n_store:], labels[n_store:]):
                res, sim, idx = store.query(x, threshold=-1.0)
                hit += int(idx is not None and res == l)
            acc = 100.0 * hit / n_query
            rows.append((f"nn_accuracy/{dataset}/tables={t}", 0.0,
                         f"accuracy_pct={acc:.2f}"))
    # (b) search time vs store size
    spec = DATASETS["cctv1"]
    X, labels = make_stream(spec, 22_000, seed=9)
    for t in (1, 5, 10):
        for n in (2_000, 10_000, 20_000):
            store = ReuseStore(LSHParams(dim=spec.dim, num_tables=t,
                                         num_probes=8, seed=7), capacity=n + 8)
            store.insert_batch(X[:n], list(labels[:n]))
            q = X[n: n + 50]
            us = timeit(lambda: [store.query(x, -1.0) for x in q], n=5) / 50
            rows.append((f"nn_search_time/tables={t}/store={n}", us,
                         f"ms_per_search={us / 1e3:.3f}"))
    return rows
