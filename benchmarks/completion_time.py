"""Paper Figs. 8a/8b (testbed) + 9a/9b (simulation): task completion times
with reuse from the CS of forwarders / from ENs vs execution from scratch."""
from __future__ import annotations

import numpy as np

from .common import DATASET_ORDER, run_network


def run(n_tasks: int = 300) -> list:
    rows = []
    for topology in ("testbed", "paper"):
        for dataset in DATASET_ORDER:
            net, s = run_network(dataset, n_tasks=n_tasks, threshold=0.9,
                                 topology=topology, rate_hz=10.0)
            cs, en, scratch = s["mean_ct_cs"], s["mean_ct_en"], s["mean_ct_scratch"]
            der = (f"ct_cs_ms={cs * 1e3:.2f};ct_en_ms={en * 1e3:.2f};"
                   f"ct_scratch_ms={scratch * 1e3:.2f}")
            if np.isfinite(cs) and cs > 0:
                der += f";speedup_cs={scratch / cs:.2f}x"
            if np.isfinite(en) and en > 0:
                der += f";speedup_en={scratch / en:.2f}x"
            rows.append((f"completion/{topology}/{dataset}", scratch * 1e6, der))
    rows.append(("completion/paper_claims", 0.0,
                 "testbed_cs=12.02-21.34x;testbed_en=5.25-6.22x;"
                 "sim_cs=6.43-12.28x;sim_en=4.25-5.11x"))
    return rows
