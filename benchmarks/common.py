"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timeit(fn: Callable, n: int = 20, warmup: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def run_network(dataset: str, *, n_tasks: int = 300, threshold: float = 0.9,
                mode: str = "reservoir", topology: str = "testbed",
                num_tables: int = None, users: int = 2, rate_hz: float = 20.0,
                measure_fwd_errors: bool = False, cs_capacity: int = 512,
                user_cs_capacity: int = 32, en_store_capacity: int = 100_000,
                seed: int = 0):
    """One simulator run -> (net, summary dict).  Mirrors §V-B / §V-C setup:
    1 LSH table for mnist/stanford_ar, 5 for the rest (unless overridden)."""
    from repro.core import LSHParams, ReservoirNetwork
    from repro.core.topology import paper_topology, testbed_topology
    from repro.data import DATASETS, dataset_service, make_stream

    spec = DATASETS[dataset]
    if num_tables is None:
        num_tables = 1 if dataset in ("mnist", "stanford_ar") else 5
    p = LSHParams(dim=spec.dim, num_tables=num_tables, num_probes=8,
                  seed=11)
    if topology == "testbed":
        g, ens = testbed_topology()
        attach = ["fwd1", "fwd2"]
    else:
        g, ens = paper_topology(seed=seed)
        attach = [n for n in g.nodes if n not in ens][:max(users, 2)]
    net = ReservoirNetwork(
        g, ens, p, mode=mode, cs_capacity=cs_capacity,
        user_cs_capacity=user_cs_capacity, en_store_capacity=en_store_capacity,
        measure_fwd_errors=measure_fwd_errors, icedge_tag_bits=10, seed=seed)
    net.register_service(dataset_service(spec))
    for u in range(users):
        net.add_user(f"u{u}", attach[u % len(attach)])
    X, _ = make_stream(spec, n_tasks, seed=seed + 1)
    t = 0.0
    for i, x in enumerate(X):
        net.submit_task(f"u{i % users}", spec.name, x, threshold, at_time=t)
        t += 1.0 / rate_hz
    net.run()
    return net, net.metrics.summary()


DATASET_ORDER = ("mnist", "pandaset", "stanford_ar", "cctv1", "cctv2")
