"""Paper §V-C cache-size study: reuse%/accuracy vs CS + store capacity (LRU).

Expected (paper): reuse%% rises with cache size until caches hold all
to-be-executed tasks, then plateaus; accuracy *decreases* slightly with
larger caches (more, older reuse candidates)."""
from __future__ import annotations

from .common import run_network

SIZES = (4, 16, 64, 256)


def run(n_tasks: int = 250) -> list:
    rows = []
    for dataset in ("cctv1", "stanford_ar"):
        parts = []
        for size in SIZES:
            _, s = run_network(dataset, n_tasks=n_tasks, threshold=0.85,
                               cs_capacity=size, user_cs_capacity=max(size // 8, 1),
                               en_store_capacity=size * 4)
            parts.append(f"cap{size}=reuse{s['reuse_pct']:.0f}pct/"
                         f"acc{s['accuracy_pct']:.0f}pct")
        rows.append((f"cache_sweep/{dataset}", 0.0, ";".join(parts)))
    return rows
