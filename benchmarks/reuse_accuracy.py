"""Paper Figs. 8c/9c: accuracy of reuse vs similarity threshold."""
from __future__ import annotations

from .common import DATASET_ORDER, run_network

THRESHOLDS = (0.5, 0.7, 0.8, 0.9, 0.95)


def run(n_tasks: int = 250) -> list:
    rows = []
    for dataset in DATASET_ORDER:
        accs = []
        for thr in THRESHOLDS:
            _, s = run_network(dataset, n_tasks=n_tasks, threshold=thr)
            accs.append(s["accuracy_pct"])
        der = ";".join(f"thr{t}={a:.1f}" for t, a in zip(THRESHOLDS, accs))
        rows.append((f"reuse_accuracy/{dataset}", 0.0,
                     der + f";paper=90-100pct at high thr"))
    return rows
