"""Paper Fig. 11 + §V-D: Reservoir vs ICedge baseline.

Paper: Reservoir ~24% lower completion time, ~26% higher reuse accuracy,
6-10us lower per-hop task forwarding time."""
from __future__ import annotations

import numpy as np

from .common import DATASET_ORDER, run_network


def run(n_tasks: int = 250) -> list:
    rows = []
    ct_r, ct_i, acc_r, acc_i = [], [], [], []
    for dataset in DATASET_ORDER:
        _, sr = run_network(dataset, n_tasks=n_tasks, threshold=0.9,
                            topology="paper")
        # 8-bit semantic tags: coarse app-level names (too few bits makes
        # ICedge artificially fast via wrong-result collisions)
        _, si = run_network(dataset, n_tasks=n_tasks, threshold=0.9,
                            topology="paper", mode="icedge")
        ct_r.append(sr_ct := _overall(sr))
        ct_i.append(si_ct := _overall(si))
        acc_r.append(sr["accuracy_pct"])
        acc_i.append(si["accuracy_pct"])
        rows.append((f"icedge/{dataset}", 0.0,
                     f"reservoir_ct_ms={sr_ct * 1e3:.1f};icedge_ct_ms={si_ct * 1e3:.1f};"
                     f"reservoir_acc={sr['accuracy_pct']:.1f};icedge_acc={si['accuracy_pct']:.1f}"))
    d_ct = 100 * (1 - np.mean(ct_r) / np.mean(ct_i))
    d_acc = np.nanmean(acc_r) - np.nanmean(acc_i)
    rows.append(("icedge/summary", 0.0,
                 f"ct_reduction={d_ct:.1f}pct (paper ~24pct);"
                 f"acc_gain={d_acc:.1f}pts (paper ~26pct)"))
    return rows


def _overall(s) -> float:
    import numpy as np

    parts, weights = [], []
    for ct, w in ((s["mean_ct_cs"], s["reuse_pct_cs"]),
                  (s["mean_ct_en"], s["reuse_pct_en"]),
                  (s["mean_ct_scratch"], 100 - s["reuse_pct"])):
        if np.isfinite(ct):
            parts.append(ct)
            weights.append(max(w, 0.0))
    return float(np.average(parts, weights=weights)) if parts else float("nan")
