"""Paper Fig. 6 + rFIB size study: task-via-rFIB vs Interest-via-FIB."""
from __future__ import annotations

import random

from repro.core import FIB, RFIB, encode_task_hash
from repro.core.rfib import partition
from .common import timeit


def run() -> list:
    rows = []
    rng = random.Random(3)
    for n_services in (100, 1_000):
        fib = FIB()
        rfib = RFIB()
        ens = [f"/edge/en{i}" for i in range(10)]
        faces = {e: [i + 1] for i, e in enumerate(ens)}
        for s in range(n_services):
            svc = f"/svc{s:04d}"
            fib.insert(svc, rng.randrange(1, 11))
            for e in partition(svc, ens, faces, num_tables=5, num_buckets=256):
                rfib.insert(e)
        svc = f"/svc{n_services // 2:04d}"
        hash_comp = encode_task_hash([rng.randrange(256) for _ in range(5)], 1)
        name = f"{svc}/task/{hash_comp}"

        fib_us = timeit(lambda: fib.lookup(name), n=200)
        rfib_us = timeit(lambda: rfib.lookup(svc, hash_comp), n=200)
        rows.append((f"fib_lookup/services={n_services}", fib_us,
                     f"us={fib_us:.2f}"))
        rows.append((f"rfib_lookup/services={n_services}", rfib_us,
                     f"us={rfib_us:.2f};overhead_us={rfib_us - fib_us:.2f};"
                     f"paper_overhead_us<=5 (once per task)"))
        rows.append((f"rfib_size/services={n_services}", 0.0,
                     f"bytes={rfib.size_bytes()};entries={len(rfib)}"))
    # paper's max config: 1K services, 100 ENs, 10 tables -> size must stay
    # far below the paper's 54.2MB bound
    big = RFIB()
    ens = [f"/metro/zone{i // 10}/en{i}" for i in range(100)]
    faces = {e: [i + 1] for i, e in enumerate(ens)}
    for s in range(1_000):
        for e in partition(f"/svc{s:04d}", ens, faces, num_tables=10,
                           num_buckets=1 << 24, index_size_bytes=4):
            big.insert(e)
    rows.append(("rfib_size/max_config", 0.0,
                 f"bytes={big.size_bytes()};entries={len(big)};paper_MB=54.2"))
    return rows
