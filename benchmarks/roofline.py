"""§Roofline: per (arch x shape) three-term roofline from dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and prints
the full baseline table: compute / memory / collective terms in seconds,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_cells(mesh: str = "16x16") -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run() -> list:
    rows = []
    cells = load_cells("16x16")
    if not cells:
        return [("roofline/missing", 0.0,
                 f"no dry-run artifacts under {ART_DIR}; run "
                 "`python -m repro.launch.dryrun --all --both-meshes` first")]
    for c in cells:
        r = c.get("roofline", {})
        name = f"roofline/{c['arch']}/{c['shape']}"
        dom_s = max(r.get("compute_s", 0), r.get("memory_s", 0),
                    r.get("collective_s", 0))
        frac = r.get("compute_s", 0.0) / max(dom_s, 1e-12)
        rows.append((name, dom_s * 1e6,
                     f"compute_s={r.get('compute_s', 0):.4f};"
                     f"memory_s={r.get('memory_s', 0):.4f};"
                     f"collective_s={r.get('collective_s', 0):.4f};"
                     f"dominant={r.get('dominant')};"
                     f"roofline_frac={frac:.3f};"
                     f"useful_flops_frac={r.get('useful_flops_frac', 0):.3f}"))
    n_multi = len(load_cells("2x16x16"))
    rows.append(("roofline/multi_pod_proof", 0.0,
                 f"cells_compiled_2x16x16={n_multi}/40"))
    return rows
