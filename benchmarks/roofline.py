"""§Roofline: per (arch x shape) three-term roofline from dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and prints
the full baseline table: compute / memory / collective terms in seconds,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also emits analytic rows for the one-dispatch fused reuse query
(ISSUE 7): per (store, batch) operating point, the hash-matmul compute
term vs the candidate-gather + top-1 memory term on v5e, with the kernel
tile knobs echoed so recorded rows are reproducible.
"""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")

# TPU v5e single-chip constants (f32 MXU rate = half the bf16 peak)
V5E_F32_FLOPS = 98.5e12
V5E_HBM_BPS = 819e9


def _fused_query_rows() -> list:
    """Analytic fused-query roofline at the benchmark operating points.

    Work model per batch of B queries (T tables, P probes, bucket cap c,
    dim D): hash matmul 2*B*T*K*D^2 flops, slot-table gather B*T*P*c*4
    bytes, candidate gather + masked top-1 B*W*D*(4 bytes + 2 flops) with
    W = T*P*c.  On v5e the candidate gather dominates everything else by
    an order of magnitude -> the fused kernel is HBM-bound and the win
    over the staged path is the removed host round-trip, not flops.
    """
    rows = []
    T, P, K, D = 5, 8, 1, 64
    bq = os.environ.get("RESERVOIR_FUSED_BLOCK_Q", "128")
    bc = os.environ.get("RESERVOIR_FUSED_BLOCK_C", "512")
    for n_store, cap in ((100_000, 25), (250_000, 62)):
        for batch in (1024, 10_000):
            w = T * P * cap
            hash_s = 2.0 * batch * T * K * D * D / V5E_F32_FLOPS
            table_s = batch * w * 4 / V5E_HBM_BPS
            gather_s = batch * w * D * 4 / V5E_HBM_BPS
            top1_s = 2.0 * batch * w * D / V5E_F32_FLOPS
            dom_s = max(hash_s, table_s + gather_s, top1_s)
            dominant = ("memory" if dom_s == table_s + gather_s else
                        "compute" if dom_s == top1_s else "hash")
            rows.append((
                f"roofline/fused_query/store{n_store}/batch{batch}",
                dom_s * 1e6,
                f"hash_s={hash_s:.2e};gather_s={table_s + gather_s:.2e};"
                f"top1_s={top1_s:.2e};dominant={dominant};"
                f"cand_width={w};block_q={bq};block_c={bc}"))
    return rows


def load_cells(mesh: str = "16x16") -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run() -> list:
    rows = _fused_query_rows()
    cells = load_cells("16x16")
    if not cells:
        rows.append(("roofline/missing", 0.0,
                     f"no dry-run artifacts under {ART_DIR}; run "
                     "`python -m repro.launch.dryrun --all --both-meshes` first"))
        return rows
    for c in cells:
        r = c.get("roofline", {})
        name = f"roofline/{c['arch']}/{c['shape']}"
        dom_s = max(r.get("compute_s", 0), r.get("memory_s", 0),
                    r.get("collective_s", 0))
        frac = r.get("compute_s", 0.0) / max(dom_s, 1e-12)
        rows.append((name, dom_s * 1e6,
                     f"compute_s={r.get('compute_s', 0):.4f};"
                     f"memory_s={r.get('memory_s', 0):.4f};"
                     f"collective_s={r.get('collective_s', 0):.4f};"
                     f"dominant={r.get('dominant')};"
                     f"roofline_frac={frac:.3f};"
                     f"useful_flops_frac={r.get('useful_flops_frac', 0):.3f}"))
    n_multi = len(load_cells("2x16x16"))
    rows.append(("roofline/multi_pod_proof", 0.0,
                 f"cells_compiled_2x16x16={n_multi}/40"))
    return rows
