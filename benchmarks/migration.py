"""Store-migration benchmark (ISSUE 8): warm-state churn on a Zipf fleet.

The stranded-store bug, measured: a warm 6-EN fleet whose rFIB partition is
Zipf-weighted (EN0 owns the lion's share) is re-partitioned to uniform
weights mid-run — a weighted rebalance that moves a large fraction of the
(table, bucket) ownership cells.  Entries admitted under the old partition
used to stay behind, so every post-rebalance near-duplicate routed to the
*new* owner missed and re-executed from scratch.  Bucket-granular store
migration ships exactly the moved ranges to their new owners over the NDN
fabric (``DESIGN.md`` §Store migration).

Arms (all share the same warm phase and the same measure stream):

  * baseline           — no churn: the steady-state local reuse-hit ceiling.
  * rebalance/stranded — weighted rebalance with ``store_migration=False``:
                         the bug, quantified (local hits collapse).
  * rebalance/migrate  — the same rebalance with migration on: local hits
                         return to the no-churn baseline.
  * autoscale          — ``AutoscalePolicy`` grows and shrinks the fleet
                         under a burst-then-trickle load while migration
                         keeps the reuse state warm; the row records the
                         reuse-hit / p99 trajectory across the run plus the
                         scaling events.

"Local reuse-hit" is the fraction of measure-phase tasks served from reuse
state *without* crossing to a remote EN — user-side cache, in-network CS, or
the routed EN's own store (named-data reuse at every layer is the point of
the paper; a rebalance that strands stores degrades exactly the EN-store
component while the name-exact caches are unaffected).  The raw EN-store
local-hit is reported alongside for the decomposition.

Acceptance (ISSUE 8), asserted outside ``--smoke``:
  * the weighted rebalance moves >= 25% of (table, bucket) ownership cells;
  * with migration, measure-phase local reuse-hit is within 5% (relative)
    of the no-churn baseline — and strictly above the stranded arm's;
  * the autoscale arm scales up AND back down, every task completes.

Standalone: ``python -m benchmarks.migration [--smoke] [--json PATH]``
(CI runs ``--smoke``); also registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import sys

import networkx as nx
import numpy as np

from benchmarks.common import Row
from repro.core import LSHParams, ReservoirNetwork
from repro.core.edge_node import Service
from repro.core.lsh import normalize
from repro.federation.policy import AutoscalePolicy

N_WARM = 400
N_MEAS = 600
N_ENS = 6
DIM = 64
THRESHOLD = 0.9
LOAD_HZ = 50.0
EN_SKEW = 1.0        # Zipf exponent of the initial bucket-partition weights
CONTENT_CENTERS = 48
CONTENT_SKEW = 1.1
CONTENT_NOISE = 0.02
EXEC_S = (0.030, 0.045)


def _topology(n_ens: int, link_delay_s: float = 0.005):
    g = nx.Graph()
    ens = [f"en{i}" for i in range(n_ens)]
    for en in ens:
        g.add_edge("core", en, delay=link_delay_s)
    return g, ens


def _zipf_stream(n: int, seed: int) -> np.ndarray:
    """Zipf-popular cluster stream.  The cluster *centers* are fixed across
    calls — the measure phase must be near-duplicates of the warm phase's
    content, or the warm store (the thing migration preserves) is moot."""
    base = normalize(np.random.default_rng(42).standard_normal(
        (CONTENT_CENTERS, DIM)).astype(np.float32))
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, CONTENT_CENTERS + 1) ** CONTENT_SKEW
    p /= p.sum()
    picks = rng.choice(CONTENT_CENTERS, n, p=p)
    return normalize(base[picks] + CONTENT_NOISE * rng.standard_normal(
        (n, DIM)).astype(np.float32))


def _owner_cells(entries, num_tables: int, num_buckets: int) -> np.ndarray:
    """(T, B) matrix of per-cell owner index (-1 = unowned): the ownership
    map whose churn the 'buckets moved' acceptance is measured on."""
    prefixes = sorted({e.en_prefix for e in entries})
    idx = {p: i for i, p in enumerate(prefixes)}
    cells = np.full((num_tables, num_buckets), -1, np.int64)
    for e in reversed(entries):  # first entry wins, like first-covering vote
        for t, (lo, hi) in e.ranges.items():
            cells[t, lo:hi + 1] = idx[e.en_prefix]
    return cells


def _make_net(n_ens: int, migration: bool, **kw) -> ReservoirNetwork:
    params = LSHParams(dim=DIM, num_tables=5, num_probes=8, seed=11)
    g, ens = _topology(n_ens)
    net = ReservoirNetwork(g, ens, params, seed=0,
                           store_migration=migration, **kw)
    net.register_service(Service(
        "/svc", execute=lambda x: round(float(np.sum(x)), 5),
        exec_time_s=EXEC_S, input_dim=DIM))
    net.add_user("u0", "core")
    net.add_user("u1", "core")
    return net


def _submit(net, X, t0: float, load_hz: float, seed: int) -> float:
    rng = np.random.default_rng(seed)
    ts = t0 + np.cumsum(rng.exponential(1.0 / load_hz, len(X)))
    for i, (t, x) in enumerate(zip(ts, X)):
        net.submit_task(f"u{i % 2}", "svc", x, THRESHOLD, at_time=float(t))
    return float(ts[-1])


def _measure(records) -> dict:
    cts = np.asarray([r.completion_time for r in records])
    n = max(len(records), 1)
    local = sum(1 for r in records
                if r.reuse is not None and r.remote_en is None)
    en_local = sum(1 for r in records
                   if r.reuse == "en" and r.remote_en is None)
    return {
        "n": len(records),
        "local_hit_pct": 100.0 * local / n,
        "en_hit_pct": 100.0 * en_local / n,
        "reuse_pct": 100.0 * sum(1 for r in records
                                 if r.reuse is not None) / n,
        "p99_ms": float(np.percentile(cts, 99)) * 1e3,
        "mean_ms": float(cts.mean()) * 1e3,
    }


def _run_churn(mode: str, n_warm: int, n_meas: int, n_ens: int) -> dict:
    """One arm: Zipf-partitioned warm phase, optional rebalance, measure."""
    net = _make_net(n_ens, migration=(mode == "migrate"))
    w = 1.0 / np.arange(1, n_ens + 1) ** EN_SKEW
    net.rebalance_service("svc", weights=list(w / w.sum()))
    t_end = _submit(net, _zipf_stream(n_warm, seed=7), 0.0, LOAD_HZ, seed=2)
    net.run()

    moved_frac = 0.0
    if mode != "baseline":
        before = _owner_cells(net.forwarders["core"].rfib.entries("svc"),
                              net.lsh_params.num_tables,
                              net.lsh_params.effective_buckets)
        net.rebalance_service("svc")  # uniform weights: undo the Zipf skew
        net.run()                     # drain the migration exchange
        after = _owner_cells(net.forwarders["core"].rfib.entries("svc"),
                             net.lsh_params.num_tables,
                             net.lsh_params.effective_buckets)
        moved_frac = float(np.mean(before != after))

    _submit(net, _zipf_stream(n_meas, seed=9), net.loop.now + 0.5,
            LOAD_HZ, seed=4)
    net.run()
    done = [r for r in net.metrics.records if r.t_complete >= 0]
    assert len(done) == n_warm + n_meas, "tasks incomplete"
    out = _measure(done[n_warm:])
    out["moved_bucket_pct"] = moved_frac * 100.0
    fs = net.federator.stats if net.federator is not None else {}
    out["migrated_entries"] = fs.get("migrated_entries", 0)
    out["migrate_batches"] = fs.get("migrate_batches", 0)
    del t_end
    return out


def _run_autoscale(n_tasks: int, windows: int = 8) -> dict:
    """Burst-then-trickle load under the autoscaler: the fleet grows, then
    shrinks, and migration keeps reuse-hit pinned through both."""
    net = _make_net(3, migration=True, offload_policy="least-loaded",
                    federation_kw={"gossip_interval_s": 0.05,
                                   "rebalance": False})
    net.rebalance_service("svc")
    policy = AutoscalePolicy(high_wait_s=0.02, low_wait_s=0.004,
                             persistence=2, cooldown_rounds=8,
                             min_ens=2, max_ens=6)
    events = []
    counter = [0]

    def up():
        counter[0] += 1
        node = f"auto{counter[0]}"
        net.add_en(node, attach_to="core")
        events.append((round(net.loop.now, 3), "add", len(net.en_nodes)))

    def down():
        node = net.en_nodes[-1]
        net.remove_en(node)
        events.append((round(net.loop.now, 3), "remove", len(net.en_nodes)))

    net.federator.attach_autoscaler(policy, up, down)
    X = _zipf_stream(n_tasks, seed=13)
    n_burst = int(n_tasks * 0.6)
    t1 = _submit(net, X[:n_burst], 0.0, 140.0, seed=5)     # overload burst
    _submit(net, X[n_burst:], t1 + 0.2, 12.0, seed=6)      # trickle: cool off
    net.run()
    done = [r for r in net.metrics.records if r.t_complete >= 0]
    assert len(done) == n_tasks, "autoscale arm: tasks incomplete"
    # reuse-hit / p99 trajectory over equal-duration submit windows
    t_lo = min(r.t_submit for r in done)
    t_hi = max(r.t_submit for r in done)
    edges = np.linspace(t_lo, t_hi + 1e-9, windows + 1)
    traj = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        win = [r for r in done if lo <= r.t_submit < hi]
        if not win:
            continue
        m = _measure(win)
        traj.append({"t": round(float(lo), 2), "n": m["n"],
                     "reuse_pct": round(m["reuse_pct"], 1),
                     "p99_ms": round(m["p99_ms"], 1)})
    fs = net.federator.stats
    return {
        "scale_ups": fs["scale_ups"], "scale_downs": fs["scale_downs"],
        "migrated_entries": fs["migrated_entries"], "events": events,
        "trajectory": traj, "overall": _measure(done),
        "final_ens": len(net.en_nodes),
    }


def _derived(r: dict) -> str:
    return (f"local_hit_pct={r['local_hit_pct']:.1f};"
            f"en_hit_pct={r['en_hit_pct']:.1f};"
            f"reuse_pct={r['reuse_pct']:.1f};p99_ms={r['p99_ms']:.1f};"
            f"mean_ms={r['mean_ms']:.1f};"
            f"moved_bucket_pct={r['moved_bucket_pct']:.1f};"
            f"migrated={r['migrated_entries']}")


def run(smoke: bool = False) -> list:
    rows: list[Row] = []
    n_warm = 150 if smoke else N_WARM
    n_meas = 150 if smoke else N_MEAS
    n_ens = 4 if smoke else N_ENS
    arms = {mode: _run_churn(mode, n_warm, n_meas, n_ens)
            for mode in ("baseline", "stranded", "migrate")}
    for mode, r in arms.items():
        rows.append((f"migration/{mode}", r["p99_ms"] * 1e3, _derived(r)))

    auto = _run_autoscale(200 if smoke else 500)
    traj = "|".join(f"t{p['t']}:reuse={p['reuse_pct']}%"
                    f",p99={p['p99_ms']}ms" for p in auto["trajectory"])
    rows.append((
        "migration/autoscale", auto["overall"]["p99_ms"] * 1e3,
        f"scale_ups={auto['scale_ups']};scale_downs={auto['scale_downs']};"
        f"final_ens={auto['final_ens']};"
        f"migrated={auto['migrated_entries']};"
        f"events={auto['events']};traj={traj}"))

    base, stranded, mig = (arms[m] for m in ("baseline", "stranded",
                                             "migrate"))
    ratio = (mig["local_hit_pct"] / base["local_hit_pct"]
             if base["local_hit_pct"] else float("nan"))
    ok = (mig["moved_bucket_pct"] >= 25.0
          and ratio >= 0.95
          and mig["local_hit_pct"] > stranded["local_hit_pct"]
          and auto["scale_ups"] >= 1 and auto["scale_downs"] >= 1)
    rows.append((
        "migration/acceptance", 0.0,
        f"moved_bucket_pct={mig['moved_bucket_pct']:.1f}(accept>=25);"
        f"local_hit_migrate/baseline={ratio:.3f}(accept>=0.95);"
        f"local_hit_stranded={stranded['local_hit_pct']:.1f}%<"
        f"migrate={mig['local_hit_pct']:.1f}%;"
        f"scale_ups={auto['scale_ups']};scale_downs={auto['scale_downs']};"
        f"{'PASS' if ok else 'FAIL'}"))
    if not ok and not smoke:
        raise AssertionError(
            f"migration acceptance: moved {mig['moved_bucket_pct']:.1f}%, "
            f"local-hit ratio {ratio:.3f}, stranded "
            f"{stranded['local_hit_pct']:.1f}% vs migrate "
            f"{mig['local_hit_pct']:.1f}%, scale {auto['scale_ups']}up/"
            f"{auto['scale_downs']}down")
    if smoke:
        # CI guard: the machinery demonstrably engaged on the small config
        assert mig["migrated_entries"] > 0, "smoke: nothing migrated"
        assert mig["moved_bucket_pct"] > 0, "smoke: rebalance moved nothing"
        assert stranded["migrated_entries"] == 0, \
            "smoke: stranded arm migrated"
        assert auto["scale_ups"] >= 1, "smoke: autoscaler never scaled up"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small configuration (CI guard)")
    ap.add_argument("--json", default=None,
                    help="also write rows to this path "
                         "(BENCH_migration.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    if args.json:
        records = [{"bench": "migration", "name": n,
                    "us_per_call": round(float(u), 2), "derived": str(d)}
                   for n, u, d in rows]
        with open(args.json, "w") as f:
            json.dump({"benches": ["migration"], "rows": records}, f,
                      indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
