"""One-dispatch fused reuse query vs the host-staged pipeline (ISSUE 7).

Sweeps store size x batch size and compares, on the *same* store,

  * ``staged`` — the PR-1 pipeline: one ``probe_batch`` dispatch, a host
    candidate-matrix build (two ``np.nonzero`` passes + per-row sort/unique),
    then the ``gathered_top1`` kernel dispatch, and
  * ``fused``  — ``ReuseStore._query_fused``: hash -> multi-probe -> device
    slot-table gather -> masked cosine top-1 -> candidate counting in a
    single jit dispatch over the device mirrors (``ops.reuse_query_top1``).

Arms are toggled via ``store.fused`` on one store and interleaved rep-by-rep
(best-of), with ``peek=True`` queries so neither arm perturbs LRU order or
statistics and both see bit-identical store state.  The derived column
records speedup, fused dispatch count per call, retrace count across the
timed reps (must be 0 on the hot path) and sync pages (must be 0 0: mirrors
are steady-state).

Acceptance (ISSUE 7): >= 3x per-task speedup at batch >= 1024 on a
>= 100k-entry store.  Block sizes honour RESERVOIR_FUSED_BLOCK_Q /
RESERVOIR_FUSED_BLOCK_C / RESERVOIR_GATHER_MODE.

``python -m benchmarks.fused_query --smoke`` runs a fast self-check used by
CI: ~20k-entry store, one 512-task batch, asserts staged/fused result
parity, that the fused path actually engaged, and exactly one device
dispatch per ``query_batch`` call.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import Row
from repro.core import LSHParams, ReuseStore, normalize
from repro.kernels import fused_query as fused_mod
from repro.kernels import ops

STORE_SIZES = (10_000, 100_000, 250_000)
BATCH_SIZES = (256, 1024, 4096, 10_000)
DIM = 64
N_REPS = 5


def _time_us(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def _make_store(n_store: int, seed: int = 0) -> tuple[ReuseStore, np.ndarray]:
    # FALCONN convention (~N buckets) keeps per-bucket fill — and with it the
    # fused candidate width T*P*cap — small relative to the store.
    p = LSHParams(dim=DIM, num_tables=5, num_probes=8, num_buckets=16384,
                  family="hyperplane", seed=11)
    store = ReuseStore(p, capacity=n_store + 1)
    rng = np.random.default_rng(seed)
    X = normalize(rng.standard_normal((n_store, DIM)).astype(np.float32))
    for lo in range(0, n_store, 8192):
        store.insert_batch(X[lo:lo + 8192],
                           list(range(lo, min(lo + 8192, n_store))))
    return store, X


def _queries(X: np.ndarray, n: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return normalize(X[:n] + 0.05 * rng.standard_normal(
        (n, DIM)).astype(np.float32) / np.sqrt(DIM))


def run(n_reps: int = N_REPS) -> list:
    rows: list[Row] = []
    knobs = (f"block_q={os.environ.get('RESERVOIR_FUSED_BLOCK_Q', '128')};"
             f"block_c={os.environ.get('RESERVOIR_FUSED_BLOCK_C', '512')};"
             f"gather={os.environ.get('RESERVOIR_GATHER_MODE', 'take')}")
    for n_store in STORE_SIZES:
        store, X = _make_store(n_store)
        queries = _queries(X, max(BATCH_SIZES))
        width = (store.params.num_tables * store.params.num_probes
                 * store.bucket_cap)
        # Warmup both arms at every batch size (jit compiles + both device
        # mirrors), then interleave staged/fused reps so bursty CPU
        # contention hits both sides of the ratio; best-of is the stable
        # capability measure.  peek=True freezes LRU/stats between arms.
        def arm(b: int, fused: bool):
            qb = queries[:b]

            def _fn():
                store.fused = fused
                store.query_batch(qb, 0.8, peek=True)
            return _fn

        fns = {(b, f): arm(b, f) for b in BATCH_SIZES for f in (False, True)}
        for fn in fns.values():
            fn()
        traces0 = fused_mod.FUSED_TRACE_COUNT
        best = {k: float("inf") for k in fns}
        for _ in range(n_reps):
            for k, fn in fns.items():
                best[k] = min(best[k], _time_us(fn))
        retraces = fused_mod.FUSED_TRACE_COUNT - traces0
        d0 = ops.FUSED_DISPATCH_COUNT
        fns[(BATCH_SIZES[0], True)]()
        dispatches = ops.FUSED_DISPATCH_COUNT - d0
        store.fused = True
        for b in BATCH_SIZES:
            us_s = best[(b, False)] / b
            us_f = best[(b, True)] / b
            rows.append((f"fused_query/staged/batch{b}/store{n_store}", us_s,
                         f"per-task best-of-{n_reps}, probe+host-matrix+"
                         f"gather kernel"))
            rows.append((f"fused_query/fused/batch{b}/store{n_store}", us_f,
                         f"per-task best-of-{n_reps}, speedup "
                         f"{us_s / us_f:.1f}x;dispatches_per_call="
                         f"{dispatches};retraces_timed={retraces};"
                         f"sync_pages={store.last_sync_pages} "
                         f"{store.last_table_sync_pages};"
                         f"cand_width={width};{knobs}"))
    return rows


def smoke() -> None:
    """CI self-check: parity + one-dispatch on a small store (seconds)."""
    store, X = _make_store(20_000)
    q = _queries(X, 512)
    store.fused = False
    staged = store.query_batch(q, 0.8, peek=True)
    store.fused = True
    assert store._use_fused(len(q)), "fused path did not engage"
    store.query_batch(q, 0.8, peek=True)  # warm: compiles + mirror uploads
    d0, t0 = ops.FUSED_DISPATCH_COUNT, fused_mod.FUSED_TRACE_COUNT
    fused = store.query_batch(q, 0.8, peek=True)
    assert ops.FUSED_DISPATCH_COUNT - d0 == 1, "hot path must be 1 dispatch"
    assert fused_mod.FUSED_TRACE_COUNT == t0, "hot path must not retrace"
    assert store.last_sync_pages == 0 and store.last_table_sync_pages == 0
    mismatch = sum(a[2] != b[2] or abs(a[1] - b[1]) > 1e-4
                   for a, b in zip(staged, fused))
    assert mismatch == 0, f"{mismatch} staged/fused result mismatches"
    hits = sum(r[2] is not None for r in fused)
    print(f"fused_query smoke ok: 512 tasks, {hits} hits, parity exact, "
          f"1 dispatch, 0 retraces, 0 sync pages")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        for name, us, derived in run():
            print(f"{name},{us:.2f},{derived}")
