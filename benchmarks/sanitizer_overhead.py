"""Sanitizer-overhead benchmark (ISSUE 9): armed vs disarmed sim cost.

The runtime sanitizer (``RESERVOIR_SANITIZE=1``, DESIGN.md §Static analysis
& sanitizers) arms invariant checks on the event-loop dispatch path, the
reuse-store sync/table mutators, and the migration ledger.  For the armed
mode to be usable in CI (the sanitized tier-1 job) it must stay cheap; for
the zero-fault bit-for-bit parity goldens to stay meaningful, the DISARMED
mode must cost nothing (a ``None``/bool test per hook).

Two interleaved best-of arms over an identical seeded workload (same
topology, same task stream, same virtual-time schedule):

* **off** — plain run, sanitizer disarmed (the production default);
* **on**  — same run with ``RESERVOIR_SANITIZE=1`` at network build time,
  arming the EventLoop context tracking, the store audits, and the PIT /
  migration idle audits.

Reported: wall us/task per arm and the armed/disarmed ratio.  Acceptance
(asserted in every mode, including ``--smoke``): armed costs < 10% wall
overhead on the smoke path, and both arms produce identical simulation
results (completion count, reuse fraction, virtual end time) — the
sanitizer observes, never perturbs.

Standalone: ``python -m benchmarks.sanitizer_overhead [--smoke] [--json P]``
(CI runs ``--smoke``); also registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import networkx as nx
import numpy as np

from benchmarks.common import Row
from repro.core import LSHParams, ReservoirNetwork
from repro.core.edge_node import Service
from repro.core.lsh import normalize

DIM = 32
N_ENS = 3
N_USERS = 2
THRESHOLD = 0.9
LOAD_HZ = 50.0
OVERHEAD_BUDGET = 0.10  # armed mode must cost < 10% on the smoke path


def _stream(n: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = normalize(rng.standard_normal((24, DIM)).astype(np.float32))
    picks = rng.integers(0, 24, n)
    return normalize(base[picks] + 0.02 * rng.standard_normal(
        (n, DIM)).astype(np.float32))


def _run_once(n_tasks: int, sanitize: bool, seed: int = 0):
    """One seeded run -> (wall seconds, result signature)."""
    params = LSHParams(dim=DIM, num_tables=3, num_probes=6, seed=11)
    g = nx.Graph()
    ens = [f"en{i}" for i in range(N_ENS)]
    for en in ens:
        g.add_edge("core", en, delay=0.002)
    env_key = "RESERVOIR_SANITIZE"
    prev = os.environ.get(env_key)
    os.environ[env_key] = "1" if sanitize else "0"
    try:
        net = ReservoirNetwork(g, ens, params, seed=seed)
    finally:
        if prev is None:
            os.environ.pop(env_key, None)
        else:
            os.environ[env_key] = prev
    assert (net.loop.sanitizer is not None) == sanitize
    net.register_service(Service(
        "/svc", execute=lambda x: round(float(np.sum(x)), 5),
        exec_time_s=(0.010, 0.015), input_dim=DIM))
    for u in range(N_USERS):
        net.add_user(f"u{u}", "core")
    X = _stream(n_tasks)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.cumsum(rng.exponential(1.0 / LOAD_HZ, n_tasks))
    t0 = time.perf_counter()
    for i, (t, x) in enumerate(zip(arrivals, X)):
        net.submit_task(f"u{i % N_USERS}", "svc", x, THRESHOLD,
                        at_time=float(t))
    net.run()
    wall = time.perf_counter() - t0
    m = net.metrics
    sig = (len(m.completed()), round(m.reuse_fraction(), 9),
           round(net.loop.now, 9))
    return wall, sig


def run(smoke: bool = True) -> list:
    """Interleaved best-of arms (same discipline as PR 3's methodology):
    alternating off/on repetitions so machine noise hits both arms alike."""
    n_tasks = 200 if smoke else 600
    reps = 3 if smoke else 5
    best = {"off": float("inf"), "on": float("inf")}
    sigs = {}
    for _ in range(reps):
        for arm, sanitize in (("off", False), ("on", True)):
            wall, sig = _run_once(n_tasks, sanitize)
            best[arm] = min(best[arm], wall)
            sigs.setdefault(arm, sig)
            if sigs[arm] != sig:
                raise AssertionError(
                    f"nondeterministic arm {arm}: {sigs[arm]} vs {sig}")
    if sigs["off"] != sigs["on"]:
        raise AssertionError(
            "sanitizer perturbed the simulation: "
            f"off={sigs['off']} on={sigs['on']}")
    ratio = best["on"] / best["off"]
    overhead_pct = (ratio - 1.0) * 100
    assert ratio < 1.0 + OVERHEAD_BUDGET, (
        f"armed sanitizer costs {overhead_pct:.1f}% "
        f"(budget {OVERHEAD_BUDGET * 100:.0f}%)")
    us = {arm: best[arm] / n_tasks * 1e6 for arm in best}
    rows: List[Row] = [
        ("sanitizer_overhead/off", us["off"],
         f"tasks={n_tasks} completed={sigs['off'][0]}"),
        ("sanitizer_overhead/on", us["on"],
         f"ratio={ratio:.3f} overhead={overhead_pct:+.1f}% "
         f"budget=<{OVERHEAD_BUDGET * 100:.0f}%"),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small task count (CI)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
