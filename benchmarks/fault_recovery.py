"""Fault-recovery benchmark (ISSUE 6): lossy links, crash-stop, recovery.

Three arms over the fault-injection layer (``src/repro/faults``):

* **loss sweep** — the TTC protocol under uniform per-link packet loss
  (0% / 1% / 5%) with consumer retransmission + exponential backoff on.
  Every NDN exchange in the TTC protocol is a short-RTT round trip (task ->
  TTC answer, fetch -> result), which is what makes a tight retransmission
  timeout principled; the sweep measures what loss costs once the protocol
  is allowed to recover: completion rate, p99 / mean completion time,
  reuse-hit rate, and retransmission volume.

* **crash-stop recovery** — a Zipf-hot hub fleet (EN0 owns most of the
  bucket partition) loses EN0 to a crash-stop mid-stream: its reuse store
  dies with it, routing keeps naming it (silence is the only signal), and
  the federation layer's telemetry-staleness detector must notice, declare
  it dead, and re-partition the rFIB while consumer retransmissions bridge
  the blackout.  Reported: time-to-detect, windowed reuse-hit dip, and
  time-to-recover (first post-crash window back within 5% of the pre-crash
  reuse-hit level).

* **zero-fault parity** — a ``ChaosController`` armed with an EMPTY
  ``FaultPlan`` must reproduce the plain simulator's summary exactly
  (the tests assert bit-for-bit on golden traces; the benchmark row keeps
  the property visible in the perf artifact).

Acceptance (ISSUE 6), asserted outside ``--smoke``:
  * 5% uniform loss with retransmission on: completion rate 100% and
    p99 <= 2x the lossless p99;
  * the crash arm detects the dead EN, shows a reuse-hit dip, and recovers
    the reuse-hit rate to within 5% of the pre-crash level.

Fault schedules are crc32-seeded (never the process-salted ``hash()``), so
every row reproduces across processes.

Standalone: ``python -m benchmarks.fault_recovery [--smoke] [--json PATH]``
(CI runs ``--smoke``); also registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
import zlib

import networkx as nx
import numpy as np

from benchmarks.common import Row
from repro.core import LSHParams, ReservoirNetwork
from repro.core.edge_node import Service
from repro.core.lsh import normalize
from repro.faults import ChaosController, FaultPlan

N_TASKS = 500
N_USERS = 3
N_ENS = 3
THRESHOLD = 0.9
LOAD_HZ = 40.0
DIM = 64
LOSS_RATES = (0.0, 0.01, 0.05)
CONTENT_CENTERS = 40
CONTENT_SKEW = 1.1
CONTENT_NOISE = 0.02
# crc32-derived plan seed: deterministic across processes
PLAN_SEED = zlib.crc32(b"reservoir-fault-recovery")
RETX = {"retx_timeout_s": 0.05, "retx_backoff": 2.0, "retx_max": 6}


def _hub(n_ens: int, link_delay_s: float = 0.005):
    g = nx.Graph()
    ens = [f"en{i}" for i in range(n_ens)]
    for en in ens:
        g.add_edge("core", en, delay=link_delay_s)
    return g, ens


def _stream(n: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = normalize(rng.standard_normal(
        (CONTENT_CENTERS, DIM)).astype(np.float32))
    p = 1.0 / np.arange(1, CONTENT_CENTERS + 1) ** CONTENT_SKEW
    p /= p.sum()
    picks = rng.choice(CONTENT_CENTERS, n, p=p)
    return normalize(base[picks] + CONTENT_NOISE * rng.standard_normal(
        (n, DIM)).astype(np.float32))


def _build(n_ens: int, plan=None, protocol="ttc", policy=None, fkw=None,
           retx=True, seed=0):
    params = LSHParams(dim=DIM, num_tables=5, num_probes=8, seed=11)
    g, ens = _hub(n_ens)
    net = ReservoirNetwork(
        g, ens, params, seed=seed, protocol=protocol,
        offload_policy=policy, federation_kw=fkw,
        **(RETX if retx else {}))
    chaos = ChaosController(net, plan) if plan is not None else None
    net.register_service(Service(
        "/svc", execute=lambda x: round(float(np.sum(x)), 5),
        exec_time_s=(0.070, 0.100), input_dim=DIM))
    for u in range(N_USERS):
        net.add_user(f"u{u}", "core")
    return net, chaos


def _drive(net, n_tasks: int, load_hz: float, seed: int = 0):
    X = _stream(n_tasks)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.cumsum(rng.exponential(1.0 / load_hz, n_tasks))
    for i, (t, x) in enumerate(zip(arrivals, X)):
        net.submit_task(f"u{i % N_USERS}", "svc", x, THRESHOLD,
                        at_time=float(t))
    net.run()
    return arrivals


# ------------------------------------------------------------- loss sweep
def _run_loss(rate: float, n_tasks: int) -> dict:
    plan = (FaultPlan.uniform_loss(rate, seed=PLAN_SEED) if rate > 0
            else FaultPlan(seed=PLAN_SEED))
    net, chaos = _build(N_ENS, plan=plan)
    _drive(net, n_tasks, LOAD_HZ)
    m = net.metrics
    done = m.completed()
    cts = np.asarray([r.completion_time for r in done]) if done else [0.0]
    return {
        "completion_pct": m.completion_rate() * 100,
        "p99_ms": float(np.percentile(cts, 99)) * 1e3,
        "mean_ms": float(np.mean(cts)) * 1e3,
        "reuse_pct": m.reuse_fraction() * 100,
        "retx": net.fault_stats["retx_sent"],
        "give_ups": net.fault_stats["retx_give_ups"],
        "drops": (chaos.stats["interest_drops"] + chaos.stats["data_drops"]),
    }


# ------------------------------------------------------------- crash arm
def _windowed_reuse(records, t_lo, t_hi, width):
    """Reuse-hit fraction per ``width``-second submission window."""
    edges = np.arange(t_lo, t_hi + width, width)
    out = []
    for lo, hi in zip(edges, edges[1:]):
        win = [r for r in records if lo <= r.t_submit < hi]
        done = [r for r in win if r.t_complete >= 0]
        if len(win) < 3:
            out.append((lo, float("nan")))
            continue
        out.append((lo, sum(r.reuse is not None for r in done) / len(win)))
    return out


def _run_crash(n_tasks: int, window_s: float = 0.25) -> dict:
    duration = n_tasks / LOAD_HZ
    t_crash = round(duration * 0.5, 3)
    plan = FaultPlan(seed=PLAN_SEED).with_crash("en0", t_crash)
    net, chaos = _build(
        N_ENS, plan=plan, protocol="ttc", policy="local-only",
        fkw={"gossip_interval_s": 0.05})
    # Zipf-hot partition: EN0 owns the lion's share, so its crash takes the
    # hot reuse content with it
    w = 1.0 / np.arange(1, N_ENS + 1)
    net.rebalance_service("svc", weights=list(w / w.sum()))
    _drive(net, n_tasks, LOAD_HZ)
    m = net.metrics
    health = net.federator.health
    detect_t = health.dead.get("en0")
    wins = _windowed_reuse(m.records, 0.0, duration, window_s)
    warmup = min(2.0, t_crash / 2)               # skip the cold-start ramp
    pre = [f for t, f in wins if t + window_s <= t_crash
           and t >= warmup and np.isfinite(f)]
    pre_level = float(np.mean(pre)) if pre else float("nan")
    post = [(t, f) for t, f in wins if t >= t_crash and np.isfinite(f)]
    dip = min((f for _, f in post), default=float("nan"))
    recover_t = next((t for t, f in post if f >= pre_level - 0.05), None)
    return {
        "completion_pct": m.completion_rate() * 100,
        "t_crash": t_crash,
        "time_to_detect_s": (detect_t - t_crash
                             if detect_t is not None else float("nan")),
        "pre_reuse_pct": pre_level * 100,
        "dip_reuse_pct": dip * 100,
        "time_to_recover_s": (recover_t - t_crash
                              if recover_t is not None else float("nan")),
        "retx": net.fault_stats["retx_sent"],
        "crash_drops": net.fault_stats["crash_drops"],
        "recovered_routing": net.fault_stats["crash_recoveries"] == 1,
        "peers_dead": net.federator.stats["peers_dead"],
    }


# --------------------------------------------------------------- parity arm
def _run_parity(n_tasks: int) -> dict:
    plain, _ = _build(N_ENS, plan=None, retx=False)
    _drive(plain, n_tasks, LOAD_HZ)
    chaotic, chaos = _build(N_ENS, plan=FaultPlan(seed=PLAN_SEED), retx=False)
    _drive(chaotic, n_tasks, LOAD_HZ)
    same = plain.metrics.summary() == chaotic.metrics.summary()
    return {"identical": same,
            "chaos_events": sum(chaos.stats.values()),
            "reuse_pct": chaotic.metrics.reuse_fraction() * 100}


def run(smoke: bool = False) -> list:
    rows: list[Row] = []
    n_tasks = 150 if smoke else N_TASKS
    loss_rates = (0.0, 0.05) if smoke else LOSS_RATES
    loss = {}
    for rate in loss_rates:
        r = _run_loss(rate, n_tasks)
        loss[rate] = r
        rows.append((
            f"fault_recovery/loss{rate * 100:.0f}pct", r["p99_ms"] * 1e3,
            f"completion={r['completion_pct']:.1f}%;"
            f"p99_ms={r['p99_ms']:.1f};mean_ms={r['mean_ms']:.1f};"
            f"reuse_pct={r['reuse_pct']:.1f};retx={r['retx']};"
            f"drops={r['drops']};give_ups={r['give_ups']}"))
    cr = _run_crash(n_tasks)
    rows.append((
        "fault_recovery/crash_en0", cr["time_to_recover_s"] * 1e6,
        f"completion={cr['completion_pct']:.1f}%;"
        f"t_crash={cr['t_crash']:.2f}s;"
        f"time_to_detect_s={cr['time_to_detect_s']:.3f};"
        f"reuse_pre={cr['pre_reuse_pct']:.1f}%;"
        f"reuse_dip={cr['dip_reuse_pct']:.1f}%;"
        f"time_to_recover_s={cr['time_to_recover_s']:.2f};"
        f"retx={cr['retx']};crash_drops={cr['crash_drops']};"
        f"routing_repartitioned={cr['recovered_routing']}"))
    par = _run_parity(min(n_tasks, 200))
    rows.append((
        "fault_recovery/zero_fault_parity", 0.0,
        f"summaries_identical={par['identical']};"
        f"chaos_events={par['chaos_events']};"
        f"reuse_pct={par['reuse_pct']:.1f}"))

    # --- acceptance (ISSUE 6)
    base, lossy = loss[0.0], loss[max(loss_rates)]
    p99_ratio = lossy["p99_ms"] / base["p99_ms"]
    # p99 over 150 smoke tasks is the ~2nd-worst sample — too noisy to hold
    # the full-run bound, so smoke only checks it stays within 3x.
    p99_accept = 3.0 if smoke else 2.0
    dipped = cr["dip_reuse_pct"] < cr["pre_reuse_pct"] - 5.0
    ok = (lossy["completion_pct"] == 100.0 and p99_ratio <= p99_accept
          and par["identical"] and cr["peers_dead"] == 1
          and cr["recovered_routing"] and dipped
          and np.isfinite(cr["time_to_recover_s"]))
    rows.append((
        "fault_recovery/acceptance", 0.0,
        f"loss5_completion={lossy['completion_pct']:.1f}%(accept=100);"
        f"p99_lossy/p99_lossless={p99_ratio:.2f}x(accept<={p99_accept:g});"
        f"crash_detected={cr['peers_dead'] == 1};"
        f"reuse_dipped={dipped};"
        f"recovered_within_5pct={np.isfinite(cr['time_to_recover_s'])};"
        f"zero_fault_parity={par['identical']};"
        f"{'PASS' if ok else 'FAIL'}"))
    if not ok and not smoke:
        raise AssertionError(
            f"fault_recovery acceptance: completion "
            f"{lossy['completion_pct']:.1f}%, p99 ratio {p99_ratio:.2f}x, "
            f"detect {cr['time_to_detect_s']:.3f}s, "
            f"recover {cr['time_to_recover_s']}s, parity {par['identical']}")
    if smoke:
        # CI guard: faults demonstrably injected and demonstrably survived
        assert loss[max(loss_rates)]["drops"] > 0, "smoke: no packets dropped"
        assert loss[max(loss_rates)]["retx"] > 0, "smoke: no retransmissions"
        assert par["identical"], "smoke: zero-fault parity broke"
        assert cr["peers_dead"] == 1, "smoke: crash never detected"
        assert ok, "smoke: acceptance row FAIL"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small configurations (CI guard)")
    ap.add_argument("--json", default=None,
                    help="also write rows to this path "
                         "(BENCH_fault_recovery.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    if args.json:
        records = [{"bench": "fault_recovery", "name": n,
                    "us_per_call": round(float(u), 2), "derived": str(d)}
                   for n, u, d in rows]
        with open(args.json, "w") as f:
            json.dump({"benches": ["fault_recovery"], "rows": records}, f,
                      indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
